//! Stochastic-engine quality, determinism and degenerate-geometry
//! guarantees:
//!
//! * the negative-sampling engine trains embeddings whose k-ary
//!   neighborhood preservation matches the Barnes–Hut engine's within
//!   0.05 on the swiss-roll workload (the estimator's noise must not
//!   cost embedding quality);
//! * its evaluations are bitwise identical across processes and across
//!   `NLE_THREADS` settings (counter-keyed per-row RNG + ordered
//!   reductions) — verified by re-running this test binary under
//!   different thread counts and comparing gradient fingerprints;
//! * a checkpointed + resumed stochastic run replays the uninterrupted
//!   run bitwise (the sampler epoch rides in the checkpoint);
//! * the `z == 0` partition-sum guard: degenerate geometry (points so
//!   far apart every pairwise kernel underflows to zero) keeps E and
//!   ∇E finite on every engine instead of producing 4λ/0 = ∞ · 0 = NaN;
//! * the coarse-to-fine multigrid schedule: its final embedding's kNN
//!   recall matches flat training on the same problem within the same
//!   0.05 bound;
//! * the grid-interpolation engine: embedding quality matches
//!   Barnes–Hut within the same 0.05 recall bound, its gradients track
//!   the exact engine within 1% on a realistic cloud, its evaluations
//!   are bitwise identical across `NLE_THREADS` (ordered reductions,
//!   serial scatter), and degenerate bounding boxes (identical points,
//!   zero-extent axes) fall back to the exact engine bitwise.

use std::sync::Arc;

use nle::linalg::sparse::SpMat;
use nle::prelude::*;

/// FNV-1a over the raw f64 bit patterns — a stable order-sensitive
/// fingerprint for bitwise gradient comparison across processes.
fn fingerprint(e: f64, g: &Mat) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut mix = |bits: u64| {
        for b in bits.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    };
    mix(e.to_bits());
    for &v in &g.data {
        mix(v.to_bits());
    }
    h
}

/// The evaluation whose bitwise fingerprint must not depend on the
/// worker count: one fresh-engine gradient eval (epoch 1) per method.
fn neg_fingerprint() -> u64 {
    let data = nle::data::synth::swiss_roll(300, 3, 0.05, 7);
    let p = nle::affinity::sne_affinities_sparse(&data.y, 8.0, 16);
    let x = nle::init::random_init(300, 2, 1.0, 5);
    let mut h: u64 = 0;
    for (method, lam) in [(Method::Ee, 100.0), (Method::Ssne, 1.0), (Method::Tsne, 1.0)] {
        let obj = NativeObjective::with_engine(
            method,
            Attractive::Sparse(p.clone()),
            lam,
            2,
            EngineSpec::NegSample { k: 8, seed: 11 },
        );
        assert_eq!(obj.engine_name(), "neg-sample");
        let (e, g) = obj.eval(&x);
        h = h.rotate_left(17) ^ fingerprint(e, &g);
    }
    h
}

/// Bitwise determinism across thread counts: the parent computes the
/// fingerprint under the ambient `NLE_THREADS`, then re-executes this
/// exact test in child processes pinned to 1 and 3 workers (the thread
/// count is read once per process, so a subprocess is the only way to
/// vary it) and demands identical bits.
#[test]
fn neg_eval_is_bitwise_identical_across_thread_counts() {
    const CHILD_ENV: &str = "NLE_QP_CHILD";
    if std::env::var(CHILD_ENV).is_ok() {
        println!("NEG_FP {:016x}", neg_fingerprint());
        return;
    }
    let here = neg_fingerprint();
    // same-process re-evaluation from a fresh engine is already bitwise
    // stable (fresh engine -> same epoch 1 -> same draws)
    assert_eq!(here, neg_fingerprint());
    for threads in ["1", "3"] {
        let out = std::process::Command::new(std::env::current_exe().unwrap())
            .args(["neg_eval_is_bitwise_identical_across_thread_counts", "--exact", "--nocapture"])
            .env(CHILD_ENV, "1")
            .env("NLE_THREADS", threads)
            .output()
            .expect("spawning the child test process");
        assert!(out.status.success(), "child with NLE_THREADS={threads} failed");
        let stdout = String::from_utf8_lossy(&out.stdout);
        let fp = stdout
            .lines()
            .find_map(|l| l.strip_prefix("NEG_FP "))
            .unwrap_or_else(|| panic!("no fingerprint in child output:\n{stdout}"));
        let fp = u64::from_str_radix(fp.trim(), 16).unwrap();
        assert_eq!(
            fp, here,
            "NLE_THREADS={threads} changed the stochastic gradient bits"
        );
    }
}

/// Small stochastic job for the checkpoint/resume replay test: sparse
/// W+, plain gradient descent (backtracking line search — its probes
/// score the gradient eval's epoch), tolerances tight enough that the
/// budget is always exhausted.
fn neg_job(max_iters: usize) -> EmbeddingJob {
    let data = nle::data::synth::swiss_roll(64, 3, 0.05, 13);
    let p = nle::affinity::sne_affinities_sparse(&data.y, 5.0, 8);
    let mut job = EmbeddingJob::native(
        "neg-ckpt",
        Method::Ee,
        10.0,
        Arc::new(Attractive::Sparse(p)),
        "gd",
        None,
    );
    job.engine = EngineSpec::NegSample { k: 4, seed: 3 };
    job.opts.max_iters = max_iters;
    job.opts.rel_tol = 1e-14;
    job.opts.grad_tol = 1e-12;
    job
}

/// A killed-and-resumed stochastic run must replay the uninterrupted
/// one bitwise: the checkpoint stamps the live sampler epoch, resume
/// restores it before the first evaluation, and every subsequent draw
/// continues the (seed, epoch, row) counter sequence.
#[test]
fn neg_checkpoint_resume_replays_bitwise() {
    let path = std::env::temp_dir().join("nle_neg_ckpt_parity.nlec");
    let job = neg_job(30);
    let mut partial = job.clone();
    partial.opts.max_iters = 12;
    partial
        .run_resumable(RunControl {
            checkpoint_every: Some(5),
            checkpoint_path: Some(path.clone()),
            ..Default::default()
        })
        .unwrap();
    let ck = TrainCheckpoint::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    // the checkpoint carries the sampler identity + live epoch
    let (seed, epoch) = ck.meta.sampler.expect("neg checkpoint must carry sampler state");
    assert_eq!(seed, 3);
    assert!(epoch > 0, "live epoch must have been stamped, got {epoch}");
    let resumed =
        job.run_resumable(RunControl { resume: Some(ck), ..Default::default() }).unwrap();
    let full = job.run().unwrap();
    assert_eq!(resumed.iters, full.iters);
    assert_eq!(resumed.stop, full.stop);
    for (a, b) in resumed.x.data.iter().zip(&full.x.data) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    for (a, b) in resumed.trace.iter().zip(&full.trace) {
        assert_eq!(a.e.to_bits(), b.e.to_bits(), "trace diverged at iter {}", a.iter);
        assert_eq!(a.nfev, b.nfev);
    }
}

/// Resume refuses a different sampler seed (a different seed is a
/// different objective realization), but accepts any epoch (the epoch
/// is state, stamped live at checkpoint time).
#[test]
fn neg_resume_rejects_a_different_seed() {
    let path = std::env::temp_dir().join("nle_neg_ckpt_seed.nlec");
    let job = neg_job(12);
    job.run_resumable(RunControl {
        checkpoint_every: Some(5),
        checkpoint_path: Some(path.clone()),
        ..Default::default()
    })
    .unwrap();
    let ck = TrainCheckpoint::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let mut other = neg_job(12);
    other.engine = EngineSpec::NegSample { k: 4, seed: 4 };
    let err = other.run_resumable(RunControl { resume: Some(ck), ..Default::default() });
    assert!(err.is_err(), "a different sampler seed must refuse to resume");
}

/// Train the same swiss roll under Barnes–Hut and under negative
/// sampling; the k-ary neighborhood preservation of the two embeddings
/// must agree within 0.05 (the acceptance bound: sampling noise shifts
/// individual coordinates, not embedding quality).
#[test]
fn neg_embedding_quality_matches_barnes_hut() {
    let n = 600;
    let data = nle::data::synth::swiss_roll(n, 3, 0.05, 42);
    let p = nle::affinity::sne_affinities_sparse(&data.y, 20.0, 60);
    let x0 = nle::init::random_init(n, 2, 1e-4, 0);
    let opts = OptOptions { max_iters: 60, ..Default::default() };
    let recall_for = |spec: EngineSpec| {
        let obj =
            NativeObjective::with_engine(Method::Ee, Attractive::Sparse(p.clone()), 100.0, 2, spec);
        let mut sd = SpectralDirection::new(Some(7));
        let res = minimize(&obj, &mut sd, &x0, &opts);
        assert!(res.e.is_finite());
        nle::metrics::knn_recall(&data.y, &res.x, 10)
    };
    let r_bh = recall_for(EngineSpec::BarnesHut { theta: 0.5 });
    let r_neg = recall_for(EngineSpec::NegSample { k: 256, seed: 1 });
    assert!(r_bh > 0.3, "BH baseline degenerated: recall {r_bh}");
    assert!(
        (r_bh - r_neg).abs() <= 0.05,
        "neighborhood agreement diverged: bh {r_bh} vs neg {r_neg}"
    );
}

/// Train the same swiss roll flat and through the coarse-to-fine
/// multigrid schedule; the k-ary neighborhood preservation of the two
/// final embeddings must agree within 0.05 (the acceptance bound: the
/// landmark detour must not cost embedding quality).
#[test]
fn multigrid_embedding_quality_matches_flat() {
    let n = 600;
    let data = nle::data::synth::swiss_roll(n, 3, 0.05, 42);
    let mk_job = || {
        let mut job = EmbeddingJob::from_data(
            "mg-parity",
            &data.y,
            Method::Ee,
            100.0,
            8.0,
            10,
            IndexSpec::Hnsw { m: 6, ef_construction: 60, ef_search: 40 },
        );
        job.strategy = "sd".to_string();
        job.opts.max_iters = 60;
        job
    };
    let flat = mk_job().run().unwrap();
    let mut staged_job = mk_job();
    staged_job.multigrid = Some(0.05);
    let staged = staged_job.run().unwrap();
    assert!(flat.e.is_finite() && staged.e.is_finite());
    let r_flat = nle::metrics::knn_recall(&data.y, &flat.x, 10);
    let r_mg = nle::metrics::knn_recall(&data.y, &staged.x, 10);
    assert!(r_flat > 0.3, "flat baseline degenerated: recall {r_flat}");
    assert!(
        (r_flat - r_mg).abs() <= 0.05,
        "neighborhood agreement diverged: flat {r_flat} vs multigrid {r_mg}"
    );
}

/// The grid-engine evaluation whose bitwise fingerprint must not
/// depend on the worker count: one gradient + one energy eval per
/// method (the energy is folded in so the shared-cache path is also
/// pinned across thread counts).
fn grid_fingerprint() -> u64 {
    let data = nle::data::synth::swiss_roll(300, 3, 0.05, 7);
    let p = nle::affinity::sne_affinities_sparse(&data.y, 8.0, 16);
    let x = nle::init::random_init(300, 2, 1.0, 5);
    let mut h: u64 = 0;
    for (method, lam) in [(Method::Ee, 100.0), (Method::Ssne, 1.0), (Method::Tsne, 1.0)] {
        let obj = NativeObjective::with_engine(
            method,
            Attractive::Sparse(p.clone()),
            lam,
            2,
            EngineSpec::GridInterp { bins: 64, order: 3 },
        );
        assert_eq!(obj.engine_name(), "grid-interp");
        let (e, g) = obj.eval(&x);
        let e2 = obj.energy(&x); // cache hit: must reuse the same grid build
        assert_eq!(e.to_bits(), e2.to_bits(), "{}: eval/energy disagree", method.name());
        h = h.rotate_left(17) ^ fingerprint(e, &g);
    }
    h
}

/// Bitwise determinism across thread counts for the deterministic grid
/// engine — same subprocess protocol as the stochastic test above: the
/// serial scatter + ordered per-point stages must make the worker
/// count invisible in the output bits.
#[test]
fn grid_eval_is_bitwise_identical_across_thread_counts() {
    const CHILD_ENV: &str = "NLE_QP_GRID_CHILD";
    if std::env::var(CHILD_ENV).is_ok() {
        println!("GRID_FP {:016x}", grid_fingerprint());
        return;
    }
    let here = grid_fingerprint();
    assert_eq!(here, grid_fingerprint(), "same-process re-eval must be bitwise stable");
    for threads in ["1", "3"] {
        let out = std::process::Command::new(std::env::current_exe().unwrap())
            .args(["grid_eval_is_bitwise_identical_across_thread_counts", "--exact", "--nocapture"])
            .env(CHILD_ENV, "1")
            .env("NLE_THREADS", threads)
            .output()
            .expect("spawning the child test process");
        assert!(out.status.success(), "child with NLE_THREADS={threads} failed");
        let stdout = String::from_utf8_lossy(&out.stdout);
        let fp = stdout
            .lines()
            .find_map(|l| l.strip_prefix("GRID_FP "))
            .unwrap_or_else(|| panic!("no fingerprint in child output:\n{stdout}"));
        let fp = u64::from_str_radix(fp.trim(), 16).unwrap();
        assert_eq!(
            fp, here,
            "NLE_THREADS={threads} changed the grid-interpolated gradient bits"
        );
    }
}

/// Train the same swiss roll under Barnes–Hut and under grid
/// interpolation; the k-ary neighborhood preservation of the two
/// embeddings must agree within 0.05 (the issue's acceptance bound:
/// the fixed interpolation error at g = 128 must not cost embedding
/// quality any more than BH's θ = 0.5 multipole error does).
#[test]
fn grid_embedding_quality_matches_barnes_hut() {
    let n = 600;
    let data = nle::data::synth::swiss_roll(n, 3, 0.05, 42);
    let p = nle::affinity::sne_affinities_sparse(&data.y, 20.0, 60);
    let x0 = nle::init::random_init(n, 2, 1e-4, 0);
    let opts = OptOptions { max_iters: 60, ..Default::default() };
    let recall_for = |spec: EngineSpec| {
        let obj =
            NativeObjective::with_engine(Method::Ee, Attractive::Sparse(p.clone()), 100.0, 2, spec);
        let mut sd = SpectralDirection::new(Some(7));
        let res = minimize(&obj, &mut sd, &x0, &opts);
        assert!(res.e.is_finite());
        nle::metrics::knn_recall(&data.y, &res.x, 10)
    };
    let r_bh = recall_for(EngineSpec::BarnesHut { theta: 0.5 });
    let r_grid = recall_for(EngineSpec::GridInterp { bins: 128, order: 3 });
    assert!(r_bh > 0.3, "BH baseline degenerated: recall {r_bh}");
    assert!(
        (r_bh - r_grid).abs() <= 0.05,
        "neighborhood agreement diverged: bh {r_bh} vs grid {r_grid}"
    );
}

/// Gradient accuracy on a realistic mid-optimization cloud: grid:128
/// cubic vs the exact engine at N = 500 must land within 1% relative
/// Frobenius error on the gradient and 1% on the energy, for both the
/// separable-Gaussian path (EE, s-SNE) and the FFT Student path
/// (t-SNE).
#[test]
fn grid_gradient_matches_exact_within_one_percent() {
    let n = 500;
    let data = nle::data::synth::swiss_roll(n, 3, 0.05, 21);
    let p = nle::affinity::sne_affinities_sparse(&data.y, 15.0, 30);
    // a spread-out X as the optimizer would see it after the early
    // expansion phase — not the 1e-4 ball the runs start from
    let x = nle::init::random_init(n, 2, 1.0, 17);
    for (method, lam) in [(Method::Ee, 100.0), (Method::Ssne, 1.0), (Method::Tsne, 1.0)] {
        let exact = NativeObjective::with_engine(
            method,
            Attractive::Sparse(p.clone()),
            lam,
            2,
            EngineSpec::Exact,
        );
        let grid = NativeObjective::with_engine(
            method,
            Attractive::Sparse(p.clone()),
            lam,
            2,
            EngineSpec::GridInterp { bins: 128, order: 3 },
        );
        let (ee, ge) = exact.eval(&x);
        let (eg, gg) = grid.eval(&x);
        let gerr = gg.rel_fro_err(&ge);
        let eerr = (eg - ee).abs() / ee.abs().max(1e-300);
        assert!(gerr < 1e-2, "{}: gradient rel err {gerr}", method.name());
        assert!(eerr < 1e-2, "{}: energy rel err {eerr}", method.name());
    }
}

/// Degenerate bounding boxes must not poison the grid build: all
/// points identical (zero extent on every axis) makes the bin width 0,
/// and the engine is contracted to fall back to the exact engine
/// *bitwise* rather than divide by it. The companion zero-extent-axis
/// case is exercised by `zero_partition_sum_stays_finite_on_every_engine`
/// below (its two points differ only along x, so the y extent is 0).
#[test]
fn grid_degenerate_bbox_falls_back_to_exact_bitwise() {
    let n = 40;
    let data = nle::data::synth::swiss_roll(n, 3, 0.05, 33);
    let p = nle::affinity::sne_affinities_sparse(&data.y, 6.0, 10);
    let x = Mat::zeros(n, 2); // every point at the origin
    for (method, lam) in [(Method::Ee, 100.0), (Method::Ssne, 1.0), (Method::Tsne, 1.0)] {
        let exact = NativeObjective::with_engine(
            method,
            Attractive::Sparse(p.clone()),
            lam,
            2,
            EngineSpec::Exact,
        );
        let grid = NativeObjective::with_engine(
            method,
            Attractive::Sparse(p.clone()),
            lam,
            2,
            EngineSpec::GridInterp { bins: 64, order: 3 },
        );
        let (ee, ge) = exact.eval(&x);
        let (eg, gg) = grid.eval(&x);
        assert_eq!(ee.to_bits(), eg.to_bits(), "{}: energy bits differ", method.name());
        for (a, b) in ge.data.iter().zip(&gg.data) {
            assert_eq!(a.to_bits(), b.to_bits(), "{}: gradient bits differ", method.name());
        }
        assert_eq!(exact.energy(&x).to_bits(), grid.energy(&x).to_bits());
    }
}

/// z-guard regression: geometry whose every repulsive kernel underflows
/// to zero (two points 1e160 apart: d² overflows, exp(−d²) and the
/// Student kernel both hit exactly 0, so the partition sum z is 0).
/// The old `scale = 4λ/z` produced ∞, then ∞ · 0 = NaN in the gradient;
/// the guarded path must stay finite on every engine.
#[test]
fn zero_partition_sum_stays_finite_on_every_engine() {
    let n = 2;
    // empty W+ so the (infinite-distance) attraction contributes 0
    let p = SpMat::from_triplets(n, n, std::iter::empty::<(usize, usize, f64)>());
    let mut x = Mat::zeros(n, 2);
    x.data[2] = 1e160; // d² = 1e320 -> inf -> kernels underflow to 0
    for method in [Method::Ssne, Method::Tsne] {
        for spec in [
            EngineSpec::Exact,
            EngineSpec::BarnesHut { theta: 0.5 },
            EngineSpec::NegSample { k: 2, seed: 0 },
            // the second axis has zero extent here, so this also pins
            // the grid engine's degenerate-bbox fallback on the z-guard
            EngineSpec::GridInterp { bins: 32, order: 3 },
        ] {
            let obj = NativeObjective::with_engine(
                method,
                Attractive::Sparse(p.clone()),
                1.0,
                2,
                spec,
            );
            let (e, g) = obj.eval(&x);
            assert!(e.is_finite(), "{} {spec:?}: energy {e}", method.name());
            assert!(
                g.data.iter().all(|v| v.is_finite()),
                "{} {spec:?}: non-finite gradient {:?}",
                method.name(),
                g.data
            );
            let e2 = obj.energy(&x);
            assert!(e2.is_finite(), "{} {spec:?}: energy() {e2}", method.name());
        }
    }
}
