//! Stochastic-engine quality, determinism and degenerate-geometry
//! guarantees:
//!
//! * the negative-sampling engine trains embeddings whose k-ary
//!   neighborhood preservation matches the Barnes–Hut engine's within
//!   0.05 on the swiss-roll workload (the estimator's noise must not
//!   cost embedding quality);
//! * its evaluations are bitwise identical across processes and across
//!   `NLE_THREADS` settings (counter-keyed per-row RNG + ordered
//!   reductions) — verified by re-running this test binary under
//!   different thread counts and comparing gradient fingerprints;
//! * a checkpointed + resumed stochastic run replays the uninterrupted
//!   run bitwise (the sampler epoch rides in the checkpoint);
//! * the `z == 0` partition-sum guard: degenerate geometry (points so
//!   far apart every pairwise kernel underflows to zero) keeps E and
//!   ∇E finite on every engine instead of producing 4λ/0 = ∞ · 0 = NaN.

use std::sync::Arc;

use nle::linalg::sparse::SpMat;
use nle::prelude::*;

/// FNV-1a over the raw f64 bit patterns — a stable order-sensitive
/// fingerprint for bitwise gradient comparison across processes.
fn fingerprint(e: f64, g: &Mat) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut mix = |bits: u64| {
        for b in bits.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    };
    mix(e.to_bits());
    for &v in &g.data {
        mix(v.to_bits());
    }
    h
}

/// The evaluation whose bitwise fingerprint must not depend on the
/// worker count: one fresh-engine gradient eval (epoch 1) per method.
fn neg_fingerprint() -> u64 {
    let data = nle::data::synth::swiss_roll(300, 3, 0.05, 7);
    let p = nle::affinity::sne_affinities_sparse(&data.y, 8.0, 16);
    let x = nle::init::random_init(300, 2, 1.0, 5);
    let mut h: u64 = 0;
    for (method, lam) in [(Method::Ee, 100.0), (Method::Ssne, 1.0), (Method::Tsne, 1.0)] {
        let obj = NativeObjective::with_engine(
            method,
            Attractive::Sparse(p.clone()),
            lam,
            2,
            EngineSpec::NegSample { k: 8, seed: 11 },
        );
        assert_eq!(obj.engine_name(), "neg-sample");
        let (e, g) = obj.eval(&x);
        h = h.rotate_left(17) ^ fingerprint(e, &g);
    }
    h
}

/// Bitwise determinism across thread counts: the parent computes the
/// fingerprint under the ambient `NLE_THREADS`, then re-executes this
/// exact test in child processes pinned to 1 and 3 workers (the thread
/// count is read once per process, so a subprocess is the only way to
/// vary it) and demands identical bits.
#[test]
fn neg_eval_is_bitwise_identical_across_thread_counts() {
    const CHILD_ENV: &str = "NLE_QP_CHILD";
    if std::env::var(CHILD_ENV).is_ok() {
        println!("NEG_FP {:016x}", neg_fingerprint());
        return;
    }
    let here = neg_fingerprint();
    // same-process re-evaluation from a fresh engine is already bitwise
    // stable (fresh engine -> same epoch 1 -> same draws)
    assert_eq!(here, neg_fingerprint());
    for threads in ["1", "3"] {
        let out = std::process::Command::new(std::env::current_exe().unwrap())
            .args(["neg_eval_is_bitwise_identical_across_thread_counts", "--exact", "--nocapture"])
            .env(CHILD_ENV, "1")
            .env("NLE_THREADS", threads)
            .output()
            .expect("spawning the child test process");
        assert!(out.status.success(), "child with NLE_THREADS={threads} failed");
        let stdout = String::from_utf8_lossy(&out.stdout);
        let fp = stdout
            .lines()
            .find_map(|l| l.strip_prefix("NEG_FP "))
            .unwrap_or_else(|| panic!("no fingerprint in child output:\n{stdout}"));
        let fp = u64::from_str_radix(fp.trim(), 16).unwrap();
        assert_eq!(
            fp, here,
            "NLE_THREADS={threads} changed the stochastic gradient bits"
        );
    }
}

/// Small stochastic job for the checkpoint/resume replay test: sparse
/// W+, plain gradient descent (backtracking line search — its probes
/// score the gradient eval's epoch), tolerances tight enough that the
/// budget is always exhausted.
fn neg_job(max_iters: usize) -> EmbeddingJob {
    let data = nle::data::synth::swiss_roll(64, 3, 0.05, 13);
    let p = nle::affinity::sne_affinities_sparse(&data.y, 5.0, 8);
    let mut job = EmbeddingJob::native(
        "neg-ckpt",
        Method::Ee,
        10.0,
        Arc::new(Attractive::Sparse(p)),
        "gd",
        None,
    );
    job.engine = EngineSpec::NegSample { k: 4, seed: 3 };
    job.opts.max_iters = max_iters;
    job.opts.rel_tol = 1e-14;
    job.opts.grad_tol = 1e-12;
    job
}

/// A killed-and-resumed stochastic run must replay the uninterrupted
/// one bitwise: the checkpoint stamps the live sampler epoch, resume
/// restores it before the first evaluation, and every subsequent draw
/// continues the (seed, epoch, row) counter sequence.
#[test]
fn neg_checkpoint_resume_replays_bitwise() {
    let path = std::env::temp_dir().join("nle_neg_ckpt_parity.nlec");
    let job = neg_job(30);
    let mut partial = job.clone();
    partial.opts.max_iters = 12;
    partial
        .run_resumable(RunControl {
            checkpoint_every: Some(5),
            checkpoint_path: Some(path.clone()),
            ..Default::default()
        })
        .unwrap();
    let ck = TrainCheckpoint::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    // the checkpoint carries the sampler identity + live epoch
    let (seed, epoch) = ck.meta.sampler.expect("neg checkpoint must carry sampler state");
    assert_eq!(seed, 3);
    assert!(epoch > 0, "live epoch must have been stamped, got {epoch}");
    let resumed =
        job.run_resumable(RunControl { resume: Some(ck), ..Default::default() }).unwrap();
    let full = job.run().unwrap();
    assert_eq!(resumed.iters, full.iters);
    assert_eq!(resumed.stop, full.stop);
    for (a, b) in resumed.x.data.iter().zip(&full.x.data) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    for (a, b) in resumed.trace.iter().zip(&full.trace) {
        assert_eq!(a.e.to_bits(), b.e.to_bits(), "trace diverged at iter {}", a.iter);
        assert_eq!(a.nfev, b.nfev);
    }
}

/// Resume refuses a different sampler seed (a different seed is a
/// different objective realization), but accepts any epoch (the epoch
/// is state, stamped live at checkpoint time).
#[test]
fn neg_resume_rejects_a_different_seed() {
    let path = std::env::temp_dir().join("nle_neg_ckpt_seed.nlec");
    let job = neg_job(12);
    job.run_resumable(RunControl {
        checkpoint_every: Some(5),
        checkpoint_path: Some(path.clone()),
        ..Default::default()
    })
    .unwrap();
    let ck = TrainCheckpoint::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let mut other = neg_job(12);
    other.engine = EngineSpec::NegSample { k: 4, seed: 4 };
    let err = other.run_resumable(RunControl { resume: Some(ck), ..Default::default() });
    assert!(err.is_err(), "a different sampler seed must refuse to resume");
}

/// Train the same swiss roll under Barnes–Hut and under negative
/// sampling; the k-ary neighborhood preservation of the two embeddings
/// must agree within 0.05 (the acceptance bound: sampling noise shifts
/// individual coordinates, not embedding quality).
#[test]
fn neg_embedding_quality_matches_barnes_hut() {
    let n = 600;
    let data = nle::data::synth::swiss_roll(n, 3, 0.05, 42);
    let p = nle::affinity::sne_affinities_sparse(&data.y, 20.0, 60);
    let x0 = nle::init::random_init(n, 2, 1e-4, 0);
    let opts = OptOptions { max_iters: 60, ..Default::default() };
    let recall_for = |spec: EngineSpec| {
        let obj =
            NativeObjective::with_engine(Method::Ee, Attractive::Sparse(p.clone()), 100.0, 2, spec);
        let mut sd = SpectralDirection::new(Some(7));
        let res = minimize(&obj, &mut sd, &x0, &opts);
        assert!(res.e.is_finite());
        nle::metrics::knn_recall(&data.y, &res.x, 10)
    };
    let r_bh = recall_for(EngineSpec::BarnesHut { theta: 0.5 });
    let r_neg = recall_for(EngineSpec::NegSample { k: 256, seed: 1 });
    assert!(r_bh > 0.3, "BH baseline degenerated: recall {r_bh}");
    assert!(
        (r_bh - r_neg).abs() <= 0.05,
        "neighborhood agreement diverged: bh {r_bh} vs neg {r_neg}"
    );
}

/// z-guard regression: geometry whose every repulsive kernel underflows
/// to zero (two points 1e160 apart: d² overflows, exp(−d²) and the
/// Student kernel both hit exactly 0, so the partition sum z is 0).
/// The old `scale = 4λ/z` produced ∞, then ∞ · 0 = NaN in the gradient;
/// the guarded path must stay finite on every engine.
#[test]
fn zero_partition_sum_stays_finite_on_every_engine() {
    let n = 2;
    // empty W+ so the (infinite-distance) attraction contributes 0
    let p = SpMat::from_triplets(n, n, std::iter::empty::<(usize, usize, f64)>());
    let mut x = Mat::zeros(n, 2);
    x.data[2] = 1e160; // d² = 1e320 -> inf -> kernels underflow to 0
    for method in [Method::Ssne, Method::Tsne] {
        for spec in [
            EngineSpec::Exact,
            EngineSpec::BarnesHut { theta: 0.5 },
            EngineSpec::NegSample { k: 2, seed: 0 },
        ] {
            let obj = NativeObjective::with_engine(
                method,
                Attractive::Sparse(p.clone()),
                1.0,
                2,
                spec,
            );
            let (e, g) = obj.eval(&x);
            assert!(e.is_finite(), "{} {spec:?}: energy {e}", method.name());
            assert!(
                g.data.iter().all(|v| v.is_finite()),
                "{} {spec:?}: non-finite gradient {:?}",
                method.name(),
                g.data
            );
            let e2 = obj.energy(&x);
            assert!(e2.is_finite(), "{} {spec:?}: energy() {e2}", method.name());
        }
    }
}
