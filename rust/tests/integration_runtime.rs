//! Integration tests for the XLA runtime path (L1/L2 artifacts executed
//! through PJRT) and its parity with the native backend.
//!
//! These tests require `make artifacts` to have produced
//! artifacts/manifest.txt; they are skipped (with a note) otherwise so
//! `cargo test` works on a fresh checkout.

use std::sync::Arc;

use nle::data::Rng;
use nle::linalg::dense::Mat;
use nle::objective::native::NativeObjective;
use nle::objective::xla::XlaObjective;
use nle::objective::{Attractive, Method, Objective};
use nle::runtime::ArtifactRegistry;

fn registry() -> Option<Arc<ArtifactRegistry>> {
    match ArtifactRegistry::open("artifacts") {
        Ok(r) => Some(Arc::new(r)),
        Err(e) => {
            eprintln!("skipping runtime tests (no artifacts): {e}");
            None
        }
    }
}

fn test_weights(n: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    let y = Mat::from_fn(n, 4, |_, _| rng.normal());
    nle::affinity::sne_affinities(&y, (n as f64 / 6.0).max(3.0))
}

#[test]
fn artifacts_cover_all_methods() {
    let Some(reg) = registry() else { return };
    let avail = reg.available();
    for m in [Method::Spectral, Method::Ee, Method::Ssne, Method::Tsne] {
        assert!(
            avail.iter().any(|&(mm, _, _)| mm == m),
            "no artifact for {}",
            m.name()
        );
    }
}

#[test]
fn xla_matches_native_energy_and_gradient() {
    let Some(reg) = registry() else { return };
    let n = 128; // must exist in the artifact grid
    let p = test_weights(n, 1);
    let mut rng = Rng::new(2);
    let x = Mat::from_fn(n, 2, |_, _| rng.normal());
    for (method, lam) in [
        (Method::Spectral, 0.0),
        (Method::Ee, 10.0),
        (Method::Ssne, 1.0),
        (Method::Tsne, 1.0),
    ] {
        let native = NativeObjective::with_affinities(
            method,
            Attractive::Dense(p.clone()),
            lam,
            2,
        );
        let xla = XlaObjective::new(
            reg.clone(),
            method,
            Attractive::Dense(p.clone()),
            lam,
            2,
        )
        .expect("build xla objective");
        let (e_n, g_n) = native.eval(&x);
        let (e_x, g_x) = xla.eval(&x);
        // f32 artifact vs f64 native: tolerances scale with magnitudes
        let e_tol = 1e-4 * e_n.abs().max(1.0);
        assert!(
            (e_n - e_x).abs() < e_tol,
            "{}: E native {e_n} vs xla {e_x}",
            method.name()
        );
        let g_scale = g_n.data.iter().fold(0.0f64, |m, v| m.max(v.abs())).max(1e-12);
        let g_diff = g_n.max_abs_diff(&g_x);
        assert!(
            g_diff < 1e-3 * g_scale,
            "{}: gradient diff {g_diff} (scale {g_scale})",
            method.name()
        );
    }
}

#[test]
fn xla_lambda_is_runtime_input() {
    // one artifact serves the whole homotopy path: changing lambda
    // changes E without recompiling
    let Some(reg) = registry() else { return };
    let n = 128;
    let p = test_weights(n, 3);
    let mut rng = Rng::new(4);
    let x = Mat::from_fn(n, 2, |_, _| rng.normal());
    let mut obj =
        XlaObjective::new(reg, Method::Ee, Attractive::Dense(p), 1.0, 2).unwrap();
    let (e1, _) = obj.eval(&x);
    obj.set_lambda(50.0);
    let (e2, _) = obj.eval(&x);
    assert!(e2 > e1, "lambda increase must increase EE energy ({e1} -> {e2})");
}

#[test]
fn xla_executable_cache_reuses_compilations() {
    let Some(reg) = registry() else { return };
    let e1 = reg.executable(Method::Ee, 128, 2).unwrap();
    let e2 = reg.executable(Method::Ee, 128, 2).unwrap();
    assert!(Arc::ptr_eq(&e1, &e2), "executable not cached");
}

#[test]
fn missing_shape_gives_helpful_error() {
    let Some(reg) = registry() else { return };
    let err = match reg.executable(Method::Ee, 12345, 2) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("expected an error for a missing shape"),
    };
    assert!(err.contains("12345"), "error should name the missing shape: {err}");
    assert!(err.contains("make artifacts"), "error should say how to fix: {err}");
}

#[test]
fn full_optimization_on_xla_backend() {
    // the three-layer hot path end-to-end: SD + line search with every
    // energy/gradient evaluation flowing through PJRT
    let Some(reg) = registry() else { return };
    let n = 128;
    let p = test_weights(n, 5);
    let obj = XlaObjective::new(reg, Method::Ee, Attractive::Dense(p), 20.0, 2).unwrap();
    let x0 = nle::init::random_init(n, 2, 1e-3, 6);
    let mut sd = nle::opt::sd::SpectralDirection::new(None);
    let res = nle::opt::minimize(
        &obj,
        &mut sd,
        &x0,
        &nle::opt::OptOptions { max_iters: 60, ..Default::default() },
    );
    assert!(res.e < res.trace[0].e * 0.5, "insufficient decrease on XLA path");
    assert!(obj.eval_count() > 60, "evaluations must flow through PJRT");
}
