//! Integration tests for the optimization stack: every strategy on every
//! method, end-to-end embedding quality, homotopy path behaviour, rate
//! ordering (theorem 2.1), and the paper's qualitative claims at test
//! scale.

use nle::affinity::sne_affinities;
use nle::data::{synth, Rng};
use nle::linalg::dense::Mat;
use nle::metrics::quality::{knn_recall, label_knn_accuracy};
use nle::objective::hessian::{full_hessian, rate_constant, sd_partial_hessian};
use nle::objective::native::NativeObjective;
use nle::objective::{Attractive, Method, Objective};
use nle::opt::homotopy::{homotopy, log_lambda_schedule};
use nle::opt::{minimize, strategy_by_name, DirectionStrategy, OptOptions, StopReason, ALL_STRATEGIES};

fn small_problem(
    n: usize,
    method: Method,
    lam: f64,
    seed: u64,
) -> (NativeObjective, Mat) {
    let mut rng = Rng::new(seed);
    let y = Mat::from_fn(n, 5, |_, _| rng.normal());
    let p = sne_affinities(&y, (n as f64 / 5.0).max(3.0));
    let obj = NativeObjective::with_affinities(method, Attractive::Dense(p), lam, 2);
    let x0 = Mat::from_fn(n, 2, |_, _| 1e-2 * rng.normal());
    (obj, x0)
}

#[test]
fn every_strategy_decreases_every_method() {
    for (method, lam) in [
        (Method::Ee, 50.0),
        (Method::Ssne, 1.0),
        (Method::Tsne, 1.0),
    ] {
        for name in ALL_STRATEGIES {
            let (obj, x0) = small_problem(24, method, lam, 7);
            let mut s = strategy_by_name(name, None).unwrap();
            let res = minimize(
                &obj,
                s.as_mut(),
                &x0,
                &OptOptions { max_iters: 40, ..Default::default() },
            );
            assert!(
                res.e < res.trace[0].e,
                "{name} failed to decrease {} (E {} -> {})",
                method.name(),
                res.trace[0].e,
                res.e
            );
            assert_ne!(res.stop, StopReason::LineSearchFailed, "{name}/{}", method.name());
        }
    }
}

#[test]
fn sd_beats_gd_by_an_order_of_magnitude_in_iterations() {
    // the paper's headline at miniature scale: iterations to reach the
    // same energy threshold differ by >= 10x between SD and GD
    let (obj, x0) = small_problem(40, Method::Ee, 20.0, 11);
    let mut sd = nle::opt::sd::SpectralDirection::new(None);
    let rs = minimize(
        &obj,
        &mut sd,
        &x0,
        &OptOptions { max_iters: 400, rel_tol: 1e-10, ..Default::default() },
    );
    let target = rs.e * 1.02; // within 2% of SD's minimum
    let sd_iters = rs
        .trace
        .iter()
        .position(|t| t.e <= target)
        .unwrap_or(rs.trace.len());
    let mut gd = nle::opt::gd::GradientDescent::new();
    let rg = minimize(
        &obj,
        &mut gd,
        &x0,
        &OptOptions { max_iters: 4000, rel_tol: 1e-14, ..Default::default() },
    );
    let gd_iters = rg
        .trace
        .iter()
        .position(|t| t.e <= target)
        .unwrap_or(10 * rg.trace.len()); // never reached: count as 10x budget
    assert!(
        gd_iters >= 10 * sd_iters.max(1),
        "sd {sd_iters} vs gd {gd_iters} iterations to target"
    );
}

#[test]
fn swiss_roll_embedding_preserves_neighborhoods() {
    let ds = synth::swiss_roll(150, 3, 0.02, 3);
    let p = sne_affinities(&ds.y, 12.0);
    let obj = NativeObjective::with_affinities(Method::Ee, Attractive::Dense(p), 100.0, 2);
    // spectral (Laplacian eigenmaps) initialization, as the paper
    // recommends for nonconvex embeddings, then SD refinement
    let p_sparse = nle::linalg::sparse::SpMat::from_dense(&obj.attractive().to_dense(), 0.0);
    let x0 = nle::init::spectral_init(&p_sparse, 2, 1.0, 4);
    let mut sd = nle::opt::sd::SpectralDirection::new(None);
    let res = minimize(
        &obj,
        &mut sd,
        &x0,
        &OptOptions { max_iters: 400, ..Default::default() },
    );
    let recall = knn_recall(&ds.y, &res.x, 10);
    assert!(recall > 0.4, "knn recall too low: {recall}");
}

#[test]
fn clusters_separate_in_embedding() {
    let ds = synth::clusters(100, 5, 16, 20.0, 5);
    let p = sne_affinities(&ds.y, 10.0);
    let obj = NativeObjective::with_affinities(Method::Ssne, Attractive::Dense(p), 1.0, 2);
    let x0 = nle::init::random_init(100, 2, 1e-3, 2);
    let mut sd = nle::opt::sd::SpectralDirection::new(None);
    let res = minimize(
        &obj,
        &mut sd,
        &x0,
        &OptOptions { max_iters: 300, ..Default::default() },
    );
    let acc = label_knn_accuracy(&res.x, &ds.labels, 5);
    assert!(acc > 0.9, "label knn accuracy {acc}");
}

#[test]
fn homotopy_reaches_deeper_or_equal_minimum_than_direct() {
    // fig. 3's motivation: homotopy "usually finds a deeper minimum"
    let (mut obj, x0) = small_problem(30, Method::Ee, 100.0, 13);
    let lambdas = log_lambda_schedule(1e-4, 100.0, 12);
    let opts = OptOptions { max_iters: 400, rel_tol: 1e-7, ..Default::default() };
    let mut sd1 = nle::opt::sd::SpectralDirection::new(None);
    let hres = homotopy(&mut obj, &mut sd1, &x0, &lambdas, &opts, None);
    let e_homotopy = hres.stages.last().unwrap().e;
    obj.set_lambda(100.0);
    let mut sd2 = nle::opt::sd::SpectralDirection::new(None);
    let direct = minimize(&obj, &mut sd2, &x0, &opts);
    assert!(
        e_homotopy <= direct.e * 1.05,
        "homotopy {e_homotopy} vs direct {}",
        direct.e
    );
}

#[test]
fn rate_constants_shrink_as_partial_hessian_approaches_full() {
    // th. 2.1: r = ||B^-1 H - I|| governs the local rate and shrinks as
    // B approaches H. Two robust instances of that claim:
    //  (a) B = H gives r ~ 0 (Newton);
    //  (b) B = 4 L+ approaches H as lambda -> 0 (the spectral limit),
    //      so r(SD) must increase monotonically with lambda.
    let mut r_prev = -1.0;
    for lam in [0.2, 1.0, 5.0] {
        let (obj, x0) = small_problem(16, Method::Ee, lam, 17);
        let mut sd = nle::opt::sd::SpectralDirection::new(None);
        let res = minimize(
            &obj,
            &mut sd,
            &x0,
            &OptOptions { max_iters: 3000, grad_tol: 1e-9, rel_tol: 1e-15, ..Default::default() },
        );
        let h = full_hessian(&obj, &res.x);
        let nd = 32;
        let mut h_reg = h.clone();
        for i in 0..nd {
            *h_reg.at_mut(i, i) += 1e-8;
        }
        // (a) Newton reference
        let r_newton = rate_constant(&h_reg, &h_reg);
        assert!(r_newton < 1e-6, "r(Newton) = {r_newton}");
        // (b) SD rate grows with lambda
        let mut b_sd = sd_partial_hessian(&obj, 2);
        for i in 0..nd {
            *b_sd.at_mut(i, i) += 1e-8;
        }
        let r_sd = rate_constant(&b_sd, &h_reg);
        assert!(
            r_sd > r_prev,
            "r(SD) not increasing with lambda: {r_sd} after {r_prev}"
        );
        r_prev = r_sd;
    }
}

#[test]
fn tsne_frozen_laplacian_still_converges() {
    // section 3.2: for t-SNE the SD factor is built once (L+ at X = 0)
    // and frozen; directions must stay descent and the optimizer must
    // make steady progress
    let (obj, x0) = small_problem(30, Method::Tsne, 1.0, 19);
    let mut sd = nle::opt::sd::SpectralDirection::new(None);
    let res = minimize(
        &obj,
        &mut sd,
        &x0,
        &OptOptions { max_iters: 150, ..Default::default() },
    );
    assert!(res.e < res.trace[0].e * 0.99);
    for w in res.trace.windows(2) {
        assert!(w[1].e <= w[0].e + 1e-10);
    }
}

#[test]
fn kappa_zero_sd_equals_fp_directions() {
    // section 2 refinement 3: kappa = 0 degenerates SD to the FP diagonal
    let (obj, x0) = small_problem(20, Method::Ee, 10.0, 23);
    let (_, g) = obj.eval(&x0);
    let mut sd0 = nle::opt::sd::SpectralDirection::new(Some(0));
    sd0.prepare(&obj, &x0).unwrap();
    let p_sd = sd0.direction(&obj, &x0, &g, 0);
    let mut fp = nle::opt::fp::FixedPoint::new();
    fp.prepare(&obj, &x0).unwrap();
    let p_fp = fp.direction(&obj, &x0, &g, 0);
    // kappa = 0 keeps no off-diagonal weights: L+ becomes the zero
    // matrix, so B = mu I — proportional to, not equal to, FP's 4 D+.
    // Both must be strict descent; check angle between them instead.
    let cos = nle::linalg::vecops::dot(&p_sd.data, &p_fp.data)
        / (nle::linalg::vecops::nrm2(&p_sd.data) * nle::linalg::vecops::nrm2(&p_fp.data));
    assert!(cos > 0.5, "kappa=0 SD and FP disagree: cos {cos}");
}

#[test]
fn time_budget_is_respected() {
    let (obj, x0) = small_problem(40, Method::Ee, 50.0, 29);
    let mut sd = nle::opt::sd::SpectralDirection::new(None);
    let t0 = std::time::Instant::now();
    let res = minimize(
        &obj,
        &mut sd,
        &x0,
        &OptOptions {
            max_iters: usize::MAX,
            time_budget: Some(std::time::Duration::from_millis(300)),
            rel_tol: 1e-16,
            grad_tol: 0.0,
            ..Default::default()
        },
    );
    assert!(t0.elapsed().as_secs_f64() < 3.0, "budget wildly exceeded");
    assert_eq!(res.stop, StopReason::TimeBudget);
}
