//! Property-based tests over the library's core invariants.
//!
//! The offline build has no proptest; `Cases` below is a small in-tree
//! driver: seeded random instances, many cases per property, failure
//! messages carrying the seed for reproduction.

use nle::affinity::{sne_affinities, sparsify_weights};
use nle::data::Rng;
use nle::graph::{laplacian_dense, laplacian_sparse};
use nle::linalg::chol;
use nle::linalg::dense::Mat;
use nle::linalg::ordering::rcm;
use nle::linalg::spchol::cholesky_sparse;
use nle::linalg::sparse::SpMat;
use nle::linalg::vecops::{dot, nrm2};
use nle::objective::native::NativeObjective;
use nle::objective::{Attractive, Method, Objective};
use nle::opt::linesearch::backtracking;

/// Mini property-test driver: `n_cases` seeded instances of a property.
struct Cases {
    n_cases: usize,
    base_seed: u64,
}

impl Cases {
    fn new(n_cases: usize, base_seed: u64) -> Self {
        Cases { n_cases, base_seed }
    }

    fn run(&self, prop: impl Fn(&mut Rng, u64)) {
        for i in 0..self.n_cases {
            let seed = self.base_seed.wrapping_add(i as u64);
            let mut rng = Rng::new(seed);
            prop(&mut rng, seed);
        }
    }
}

/// Random symmetric nonnegative weights with zero diagonal.
fn rand_weights(rng: &mut Rng, n: usize) -> Mat {
    let mut w = Mat::from_fn(n, n, |_, _| rng.uniform());
    for i in 0..n {
        *w.at_mut(i, i) = 0.0;
        for j in 0..i {
            let v = w.at(i, j);
            *w.at_mut(j, i) = v;
        }
    }
    w
}

/// Random spd sparse matrix (ring graph + random chords, diagonally
/// dominant so it is pd).
fn rand_spd_sparse(rng: &mut Rng, n: usize) -> SpMat {
    let mut trip = Vec::new();
    for i in 0..n {
        trip.push((i, i, 2.0 + rng.uniform() * 3.0));
        let j = (i + 1) % n;
        let v = -rng.uniform();
        trip.push((i, j, v));
        trip.push((j, i, v));
        if rng.uniform() < 0.3 {
            let k = rng.below(n);
            if k != i {
                let v2 = -0.5 * rng.uniform();
                trip.push((i, k, v2));
                trip.push((k, i, v2));
            }
        }
    }
    let a = SpMat::from_triplets(n, n, trip);
    let mut diag_boost = vec![0.0; n];
    for c in 0..n {
        for p in a.colptr[c]..a.colptr[c + 1] {
            if a.rowind[p] != c {
                diag_boost[c] += a.values[p].abs();
            }
        }
    }
    let boost = SpMat::from_triplets(n, n, (0..n).map(|i| (i, i, diag_boost[i] + 0.1)));
    a.add(&boost)
}

#[test]
fn prop_laplacian_psd_and_zero_rowsum() {
    Cases::new(25, 100).run(|rng, seed| {
        let n = 3 + rng.below(20);
        let w = rand_weights(rng, n);
        let l = laplacian_dense(&w);
        for i in 0..n {
            let s: f64 = l.row(i).iter().sum();
            assert!(s.abs() < 1e-10, "seed {seed}: row sum {s}");
        }
        for _ in 0..5 {
            let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let q = dot(&u, &l.matvec(&u));
            assert!(q >= -1e-10, "seed {seed}: quadratic form {q}");
        }
    });
}

#[test]
fn prop_sparse_dense_laplacian_agree() {
    Cases::new(20, 200).run(|rng, seed| {
        let n = 3 + rng.below(15);
        let w = rand_weights(rng, n);
        let ld = laplacian_dense(&w);
        let ls = laplacian_sparse(&SpMat::from_dense(&w, 0.0));
        assert!(
            ls.to_dense().max_abs_diff(&ld) < 1e-12,
            "seed {seed}: sparse != dense Laplacian"
        );
    });
}

#[test]
fn prop_sparse_cholesky_matches_dense() {
    Cases::new(20, 300).run(|rng, seed| {
        let n = 4 + rng.below(30);
        let a = rand_spd_sparse(rng, n);
        let sp = cholesky_sparse(&a).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        let ld = chol::cholesky(&a.to_dense()).unwrap();
        let diff = sp.l.to_dense().max_abs_diff(&ld);
        assert!(diff < 1e-8, "seed {seed}: factor diff {diff}");
    });
}

#[test]
fn prop_cholesky_solve_residual() {
    Cases::new(20, 400).run(|rng, seed| {
        let n = 4 + rng.below(40);
        let a = rand_spd_sparse(rng, n);
        let sp = cholesky_sparse(&a).unwrap();
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut x = b.clone();
        sp.solve(&mut x);
        let r = a.matvec(&x);
        let bn = nrm2(&b).max(1e-12);
        for i in 0..n {
            assert!(
                (r[i] - b[i]).abs() < 1e-8 * bn,
                "seed {seed}: residual {} at {i}",
                r[i] - b[i]
            );
        }
    });
}

#[test]
fn prop_rcm_permutation_preserves_solution() {
    Cases::new(15, 500).run(|rng, seed| {
        let n = 5 + rng.below(25);
        let a = rand_spd_sparse(rng, n);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut x_direct = b.clone();
        cholesky_sparse(&a).unwrap().solve(&mut x_direct);
        let perm = rcm(&a);
        let ap = a.sym_perm(&perm);
        let chol = cholesky_sparse(&ap).unwrap();
        let mut bp: Vec<f64> = (0..n).map(|i| b[perm[i]]).collect();
        chol.solve(&mut bp);
        for i in 0..n {
            assert!(
                (bp[i] - x_direct[perm[i]]).abs() < 1e-7,
                "seed {seed}: permuted solve mismatch"
            );
        }
    });
}

#[test]
fn prop_entropic_affinities_are_a_distribution() {
    Cases::new(8, 600).run(|rng, seed| {
        let n = 10 + rng.below(30);
        let d = 2 + rng.below(4);
        let y = Mat::from_fn(n, d, |_, _| rng.normal());
        let perp = 3.0 + rng.uniform() * (n as f64 / 3.0 - 3.0);
        let p = sne_affinities(&y, perp);
        let total: f64 = p.data.iter().sum();
        assert!((total - 1.0).abs() < 1e-8, "seed {seed}: sum {total}");
        assert!(p.asymmetry() < 1e-12, "seed {seed}");
        assert!(p.data.iter().all(|&v| v >= 0.0), "seed {seed}: negative affinity");
    });
}

#[test]
fn prop_sparsify_keeps_symmetry_and_nonnegativity() {
    Cases::new(15, 700).run(|rng, seed| {
        let n = 6 + rng.below(20);
        let w = rand_weights(rng, n);
        let kappa = 1 + rng.below(n - 2);
        let s = sparsify_weights(&w, kappa);
        assert!(s.asymmetry() < 1e-12, "seed {seed}");
        assert!(s.values.iter().all(|&v| v >= 0.0), "seed {seed}");
        assert!(s.nnz() <= w.rows * 2 * kappa, "seed {seed}: too dense");
    });
}

#[test]
fn prop_native_gradient_matches_finite_differences() {
    Cases::new(6, 800).run(|rng, seed| {
        let n = 6 + rng.below(10);
        let w = rand_weights(rng, n);
        let methods = [
            (Method::Ee, 1.0 + rng.uniform() * 20.0),
            (Method::Ssne, 1.0),
            (Method::Tsne, 1.0),
        ];
        let (method, lam) = methods[rng.below(3)];
        let obj = NativeObjective::with_affinities(method, Attractive::Dense(w), lam, 2);
        let x = Mat::from_fn(n, 2, |_, _| rng.normal());
        let (_, g) = obj.eval(&x);
        let eps = 1e-6;
        for _ in 0..4 {
            let (i, j) = (rng.below(n), rng.below(2));
            let mut xp = x.clone();
            *xp.at_mut(i, j) += eps;
            let mut xm = x.clone();
            *xm.at_mut(i, j) -= eps;
            let fd = (obj.energy(&xp) - obj.energy(&xm)) / (2.0 * eps);
            let gv = g.at(i, j);
            assert!(
                (fd - gv).abs() < 1e-4 * gv.abs().max(1.0),
                "seed {seed} {}: fd {fd} vs {gv}",
                method.name()
            );
        }
    });
}

#[test]
fn prop_every_strategy_produces_descent_directions() {
    Cases::new(5, 900).run(|rng, seed| {
        let n = 10 + rng.below(10);
        let y = Mat::from_fn(n, 4, |_, _| rng.normal());
        let p = sne_affinities(&y, (n as f64 / 4.0).max(2.0));
        let obj =
            NativeObjective::with_affinities(Method::Ee, Attractive::Dense(p), 10.0, 2);
        let x = Mat::from_fn(n, 2, |_, _| 0.3 * rng.normal());
        let (_, g) = obj.eval(&x);
        for name in nle::opt::ALL_STRATEGIES {
            let mut s = nle::opt::strategy_by_name(name, None).unwrap();
            s.prepare(&obj, &x).unwrap();
            let p_dir = s.direction(&obj, &x, &g, 0);
            let gtp = dot(&p_dir.data, &g.data);
            assert!(gtp < 0.0, "seed {seed}: {name} gave non-descent gtp = {gtp}");
        }
    });
}

#[test]
fn prop_line_search_guarantees_sufficient_decrease() {
    Cases::new(10, 1000).run(|rng, seed| {
        let n = 8 + rng.below(12);
        let w = rand_weights(rng, n);
        let obj =
            NativeObjective::with_affinities(Method::Ee, Attractive::Dense(w), 5.0, 2);
        let x = Mat::from_fn(n, 2, |_, _| rng.normal());
        let (e0, g) = obj.eval(&x);
        let p = Mat::from_vec(n, 2, g.data.iter().map(|v| -v).collect());
        let gtp = dot(&g.data, &p.data);
        let res = backtracking(&obj, &x, &p, e0, gtp, 1.0, 1e-4, 60);
        assert!(res.success, "seed {seed}");
        assert!(
            res.e_new <= e0 + 1e-4 * res.alpha * gtp + 1e-9 * e0.abs(),
            "seed {seed}: armijo violated"
        );
    });
}

#[test]
fn prop_energies_decrease_monotonically_under_optimizer() {
    Cases::new(4, 1100).run(|rng, seed| {
        let n = 12;
        let y = Mat::from_fn(n, 3, |_, _| rng.normal());
        let p = sne_affinities(&y, 4.0);
        let method = [Method::Ee, Method::Ssne, Method::Tsne][rng.below(3)];
        let lam = if method == Method::Ee { 10.0 } else { 1.0 };
        let obj = NativeObjective::with_affinities(method, Attractive::Dense(p), lam, 2);
        let x0 = Mat::from_fn(n, 2, |_, _| 0.1 * rng.normal());
        let mut sd = nle::opt::sd::SpectralDirection::new(None);
        let res = nle::opt::minimize(
            &obj,
            &mut sd,
            &x0,
            &nle::opt::OptOptions { max_iters: 50, ..Default::default() },
        );
        for w in res.trace.windows(2) {
            assert!(
                w[1].e <= w[0].e + 1e-9 * w[0].e.abs().max(1.0),
                "seed {seed}: energy increased {} -> {}",
                w[0].e,
                w[1].e
            );
        }
    });
}

#[test]
fn prop_knn_symmetrized_edges_unique() {
    Cases::new(10, 1200).run(|rng, seed| {
        let n = 10 + rng.below(20);
        let y = Mat::from_fn(n, 3, |_, _| rng.normal());
        let k = 1 + rng.below(5);
        let g = nle::affinity::knn(&y, k);
        let edges = g.sym_edges();
        let mut seen = std::collections::HashSet::new();
        for &(i, j, d2) in &edges {
            assert!(i < j, "seed {seed}");
            assert!(d2 >= 0.0, "seed {seed}");
            assert!(seen.insert((i, j)), "seed {seed}: duplicate edge ({i},{j})");
        }
    });
}
