//! Cross-index parity: the HNSW backend against the exact reference.
//!
//! * recall ≥ 0.9 at k = 10 on a 2000-point swiss roll (the
//!   acceptance bound; the default knobs land well above it);
//! * entropic affinities built over exact vs HNSW neighborhoods agree
//!   in their per-point perplexities within tolerance — approximate
//!   neighbors perturb *which* tail entries a row keeps, not the
//!   calibrated scale;
//! * the full large-N pipeline shape — HNSW affinities + Barnes–Hut
//!   engine + spectral direction — descends end to end and matches the
//!   exact pipeline's embedding quality.

use nle::affinity::{row_perplexity, sne_affinities_sparse_with};
use nle::index::{graph_recall, IndexSpec, knn_graph};
use nle::prelude::*;

fn swiss(n: usize) -> Mat {
    nle::data::synth::swiss_roll(n, 3, 0.05, 42).y
}

#[test]
fn hnsw_recall_on_swiss_roll() {
    let y = swiss(2000);
    let exact = knn_graph(&y, 10, IndexSpec::Exact);
    let hnsw = knn_graph(&y, 10, IndexSpec::hnsw_default());
    let r = graph_recall(&exact, &hnsw);
    assert!(r >= 0.9, "recall {r} < 0.9 at k = 10 on 2000-pt swiss roll");
}

#[test]
fn auto_spec_flips_to_hnsw_at_threshold() {
    use nle::index::AUTO_HNSW_MIN_N;
    let y = swiss(AUTO_HNSW_MIN_N);
    assert_eq!(IndexSpec::Auto.build(&y).name(), "hnsw");
    let small = swiss(64);
    assert_eq!(IndexSpec::Auto.build(&small).name(), "exact");
}

#[test]
fn entropic_perplexity_parity_exact_vs_hnsw() {
    let n = 1000;
    let y = swiss(n);
    let (perp, k) = (8.0, 24);
    let pe = sne_affinities_sparse_with(&y, perp, k, IndexSpec::Exact).to_dense();
    let ph = sne_affinities_sparse_with(&y, perp, k, IndexSpec::hnsw_default()).to_dense();
    // totals agree exactly by construction (both sum to 1)
    let se: f64 = pe.data.iter().sum();
    let sh: f64 = ph.data.iter().sum();
    assert!((se - 1.0).abs() < 1e-10 && (sh - 1.0).abs() < 1e-10);
    // per-point effective perplexities track each other
    let mut max_rel = 0.0f64;
    for i in 0..n {
        let a = row_perplexity(&pe, i);
        let b = row_perplexity(&ph, i);
        max_rel = max_rel.max((a - b).abs() / a);
    }
    assert!(max_rel < 0.25, "worst per-row perplexity deviation {max_rel}");
    // and the mean deviation is far tighter
    let mean_rel: f64 = (0..n)
        .map(|i| {
            let a = row_perplexity(&pe, i);
            (row_perplexity(&ph, i) - a).abs() / a
        })
        .sum::<f64>()
        / n as f64;
    assert!(mean_rel < 0.05, "mean per-row perplexity deviation {mean_rel}");
}

#[test]
fn end_to_end_sd_on_bh_with_hnsw_affinities() {
    // the full approximate pipeline at a test-friendly N: HNSW
    // neighbor search -> entropic affinities -> Barnes-Hut engine ->
    // spectral direction with a sparse Cholesky
    let n = 1500;
    let y = swiss(n);
    let p_hnsw = sne_affinities_sparse_with(&y, 12.0, 36, IndexSpec::hnsw_default());
    let p_exact = sne_affinities_sparse_with(&y, 12.0, 36, IndexSpec::Exact);

    let run = |p: nle::linalg::sparse::SpMat| {
        let obj = NativeObjective::with_engine(
            Method::Ee,
            Attractive::Sparse(p),
            100.0,
            2,
            EngineSpec::BarnesHut { theta: 0.5 },
        );
        let x0 = nle::init::random_init(n, 2, 1e-4, 0);
        let mut sd = SpectralDirection::new(Some(7));
        minimize(&obj, &mut sd, &x0, &OptOptions { max_iters: 30, ..Default::default() })
    };
    let rh = run(p_hnsw);
    let re = run(p_exact);

    // descends monotonically and substantially
    assert!(rh.e.is_finite());
    let e0 = rh.trace.first().unwrap().e;
    assert!(rh.e < e0, "no descent: {e0} -> {}", rh.e);
    for w in rh.trace.windows(2) {
        assert!(w[1].e <= w[0].e + 1e-10);
    }
    // embedding quality on par with the exact pipeline: neighborhood
    // preservation within a few points of each other
    let q_h = nle::metrics::quality::knn_recall(&y, &rh.x, 10);
    let q_e = nle::metrics::quality::knn_recall(&y, &re.x, 10);
    assert!(
        q_h > q_e - 0.05,
        "hnsw-pipeline quality {q_h} far below exact-pipeline {q_e}"
    );
    // and the final energies are in the same regime
    let rel = (rh.e - re.e).abs() / re.e.abs().max(1e-300);
    assert!(rel < 0.05, "final energy gap {rel} (hnsw {} vs exact {})", rh.e, re.e);
}
