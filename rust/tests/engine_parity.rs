//! Cross-engine parity: the Barnes–Hut engine against the exact
//! reference semantics.
//!
//! * θ → 0 is *identical* to the exact engine (the tree opens every
//!   cell), and the approximation error shrinks as θ does;
//! * θ = 0.5 (the customary operating point) stays within 1e-2
//!   relative gradient error on a 500-point swiss-roll workload;
//! * dense and kNN-sparse attractive weights agree under both engines
//!   for all four methods;
//! * the spectral direction optimizes end-to-end on the BH engine.

use nle::linalg::sparse::SpMat;
use nle::prelude::*;

/// 500-point swiss roll: kNN-sparse affinities + a spread embedding
/// probe (scale 1.0 keeps pairwise distances O(1), so the repulsive
/// field actually matters and the test exercises the approximation).
fn swiss_setup() -> (SpMat, Mat) {
    let data = nle::data::synth::swiss_roll(500, 3, 0.05, 42);
    let p = nle::affinity::sne_affinities_sparse(&data.y, 20.0, 60);
    let x = nle::init::random_init(500, 2, 1.0, 3);
    (p, x)
}

/// Property: the BH gradient converges to the exact gradient as θ → 0,
/// is exact at θ = 0, and meets the 1e-2 bound at θ = 0.5.
#[test]
fn bh_gradient_converges_to_exact_as_theta_shrinks() {
    let (p, x) = swiss_setup();
    for (method, lam) in [(Method::Ee, 100.0), (Method::Ssne, 1.0), (Method::Tsne, 1.0)] {
        let exact = NativeObjective::with_engine(
            method,
            Attractive::Sparse(p.clone()),
            lam,
            2,
            EngineSpec::Exact,
        );
        let (e_ref, g_ref) = exact.eval(&x);
        let err_at = |theta: f64| -> (f64, f64) {
            let bh = NativeObjective::with_engine(
                method,
                Attractive::Sparse(p.clone()),
                lam,
                2,
                EngineSpec::BarnesHut { theta },
            );
            let (e, g) = bh.eval(&x);
            (g.rel_fro_err(&g_ref), (e - e_ref).abs() / e_ref.abs().max(1e-300))
        };

        let (g_coarse, _) = err_at(1.0);
        let (g_mid, e_mid) = err_at(0.5);
        let (g_fine, _) = err_at(0.05);
        let (g_zero, e_zero) = err_at(0.0);

        // acceptance bound at the customary operating point
        assert!(g_mid < 1e-2, "{}: theta=0.5 grad rel err {g_mid}", method.name());
        assert!(e_mid < 1e-2, "{}: theta=0.5 energy rel err {e_mid}", method.name());
        // convergence: finer theta is no worse than the coarse setting
        assert!(
            g_fine <= g_coarse + 1e-9,
            "{}: err(0.05) = {g_fine} > err(1.0) = {g_coarse}",
            method.name()
        );
        // theta = 0 opens every cell: exact up to summation order
        assert!(g_zero < 1e-9, "{}: theta=0 grad err {g_zero}", method.name());
        assert!(e_zero < 1e-9, "{}: theta=0 energy err {e_zero}", method.name());
    }
}

/// Dense vs kNN-sparse attractive weights must agree for all four
/// methods, under the exact engine (tight) and the BH engine at fixed
/// θ (the tree only sees X, so the representations are identical).
#[test]
fn attract_dense_sparse_parity_all_methods() {
    let n = 40;
    let mut rng = nle::data::Rng::new(9);
    let y = Mat::from_fn(n, 4, |_, _| rng.normal());
    let w = nle::affinity::sne_affinities(&y, 8.0);
    let ws = SpMat::from_dense(&w, 0.0);
    let x = Mat::from_fn(n, 2, |_, _| rng.normal());
    for (method, lam) in [
        (Method::Spectral, 0.0),
        (Method::Ee, 5.0),
        (Method::Ssne, 1.0),
        (Method::Tsne, 1.0),
    ] {
        for spec in [EngineSpec::Exact, EngineSpec::BarnesHut { theta: 0.25 }] {
            let dense = NativeObjective::with_engine(
                method,
                Attractive::Dense(w.clone()),
                lam,
                2,
                spec,
            );
            let sparse = NativeObjective::with_engine(
                method,
                Attractive::Sparse(ws.clone()),
                lam,
                2,
                spec,
            );
            let (ed, gd) = dense.eval(&x);
            let (es, gs) = sparse.eval(&x);
            assert!(
                (ed - es).abs() < 1e-9 * ed.abs().max(1.0),
                "{} [{}]: E dense {ed} vs sparse {es}",
                method.name(),
                spec.name()
            );
            assert!(
                gd.max_abs_diff(&gs) < 1e-9,
                "{} [{}]: grad mismatch {}",
                method.name(),
                spec.name(),
                gd.max_abs_diff(&gs)
            );
            // energy() must agree with eval().0 within either engine
            let e2 = dense.energy(&x);
            assert!((e2 - ed).abs() < 1e-9 * ed.abs().max(1.0));
        }
    }
}

/// `energy()` and `eval().0` must agree within the BH engine at a fixed
/// X (same tree, same θ — the cheap line-search path may not drift from
/// the gradient path). Checked for every method that builds a tree.
#[test]
fn bh_energy_consistent_with_eval() {
    let (p, x) = swiss_setup();
    for (method, lam) in [(Method::Ee, 100.0), (Method::Ssne, 1.0), (Method::Tsne, 1.0)] {
        let obj = NativeObjective::with_engine(
            method,
            Attractive::Sparse(p.clone()),
            lam,
            2,
            EngineSpec::BarnesHut { theta: 0.5 },
        );
        let (e, _) = obj.eval(&x);
        let e2 = obj.energy(&x);
        assert!(
            (e - e2).abs() < 1e-10 * e.abs().max(1.0),
            "{}: eval E {e} vs energy {e2}",
            method.name()
        );
    }
}

/// Spectral direction end-to-end on the Barnes–Hut engine: sparse W+
/// feeds the sparse-Laplacian Cholesky, the BH engine feeds gradients;
/// the energy must decrease monotonically. (The N = 20k version runs in
/// the `scal` harness; this keeps the test suite fast.)
#[test]
fn spectral_direction_runs_on_bh_engine() {
    let n = 300;
    let data = nle::data::synth::swiss_roll(n, 3, 0.05, 7);
    let p = nle::affinity::sne_affinities_sparse(&data.y, 10.0, 30);
    let obj = NativeObjective::with_engine(
        Method::Ee,
        Attractive::Sparse(p),
        50.0,
        2,
        EngineSpec::BarnesHut { theta: 0.5 },
    );
    assert_eq!(obj.engine_name(), "barnes-hut");
    let x0 = nle::init::random_init(n, 2, 1e-4, 0);
    let mut sd = SpectralDirection::new(Some(7));
    let res = minimize(
        &obj,
        &mut sd,
        &x0,
        &OptOptions { max_iters: 40, ..Default::default() },
    );
    assert!(res.e.is_finite());
    assert!(res.trace.len() > 1, "no iterations ran");
    for w in res.trace.windows(2) {
        assert!(w[1].e <= w[0].e + 1e-9 * w[0].e.abs().max(1.0), "energy increased");
    }
    let e0 = res.trace.first().unwrap().e;
    assert!(res.e < e0, "no progress: {e0} -> {}", res.e);
}

/// Auto-selection: small problems stay exact; a >= 4096-point sparse EE
/// problem flips to Barnes–Hut without any caller change.
#[test]
fn auto_selects_bh_at_scale() {
    let small = nle::affinity::sne_affinities_sparse(
        &Mat::from_fn(64, 3, |i, j| (i * 3 + j) as f64 * 0.1),
        5.0,
        10,
    );
    let obj = NativeObjective::with_affinities(Method::Ee, Attractive::Sparse(small), 1.0, 2);
    assert_eq!(obj.engine_name(), "exact");

    // a chain graph is enough to check selection without building
    // real affinities at N = 4096
    let n = 4096;
    let chain = SpMat::from_triplets(
        n,
        n,
        (1..n).flat_map(|i| [(i, i - 1, 1.0), (i - 1, i, 1.0)]),
    );
    let obj = NativeObjective::with_affinities(Method::Ee, Attractive::Sparse(chain), 1.0, 2);
    assert_eq!(obj.engine_name(), "barnes-hut");
}
