//! Resume-equivalence tests: the PR 4 contract is that a run
//! interrupted at *any* iteration and resumed from its checkpoint
//! produces a bitwise-identical final embedding and an identical
//! per-iteration trace (times excluded — wall clocks are not
//! reproducible) versus the run that was never interrupted. Checked
//! for every strategy in `ALL_STRATEGIES`, for the λ-homotopy driver,
//! for the coarse-to-fine multigrid driver (across its stage
//! boundary), and through the full encode→decode cycle of the NLEC
//! record so the codec itself is inside the loop being verified.

use nle::opt::homotopy::{homotopy_resumable, log_lambda_schedule, HomotopyState};
use nle::opt::{self, ALL_STRATEGIES};
use nle::prelude::*;

fn setup(n: usize, seed: u64) -> (NativeObjective, Mat) {
    let mut rng = nle::data::Rng::new(seed);
    let y = Mat::from_fn(n, 4, |_, _| rng.normal());
    let p = nle::affinity::sne_affinities(&y, (n as f64 / 4.0).max(2.0));
    let obj = NativeObjective::with_affinities(Method::Ee, Attractive::Dense(p), 10.0, 2);
    let x0 = Mat::from_fn(n, 2, |_, _| 0.1 * rng.normal());
    (obj, x0)
}

fn meta_for(obj: &NativeObjective, strategy: &str, n: usize) -> CheckpointMeta {
    CheckpointMeta {
        name: format!("test-{strategy}"),
        strategy: strategy.to_string(),
        kappa: None,
        method: obj.method(),
        lambda: obj.lambda(),
        dim: 2,
        n,
        engine: obj.engine_name().to_string(),
        backend: "native".to_string(),
        weights_fp: nle::model::codec::weights_fingerprint(obj.attractive()),
        sampler: obj.sampler_state(),
    }
}

/// Compare everything but wall-clock times.
fn assert_traces_identical(a: &[IterStats], b: &[IterStats], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: trace lengths differ");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.iter, y.iter, "{what}");
        assert_eq!(x.e.to_bits(), y.e.to_bits(), "{what}: E diverged at iter {}", x.iter);
        assert_eq!(
            x.grad_inf.to_bits(),
            y.grad_inf.to_bits(),
            "{what}: |g| diverged at iter {}",
            x.iter
        );
        assert_eq!(
            x.alpha.to_bits(),
            y.alpha.to_bits(),
            "{what}: alpha diverged at iter {}",
            x.iter
        );
        assert_eq!(x.nfev, y.nfev, "{what}: nfev diverged at iter {}", x.iter);
    }
}

fn assert_bitwise_equal(a: &Mat, b: &Mat, what: &str) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "{what}: shapes differ");
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: embedding bit-diverged at entry {i}");
    }
}

#[test]
fn every_strategy_resumes_bitwise_identically() {
    for &name in ALL_STRATEGIES {
        let n = 22;
        let (obj, x0) = setup(n, 3);
        let opts = OptOptions {
            max_iters: 30,
            rel_tol: 1e-13,
            grad_tol: 1e-12,
            ..Default::default()
        };
        // the run that is never interrupted
        let mut s_full = opt::strategy_by_name(name, None).unwrap();
        let full = opt::try_minimize(&obj, s_full.as_mut(), &x0, &opts).unwrap();
        assert!(full.iters() > 6, "{name}: test needs a run longer than the checkpoint point");

        // the same run, checkpointed after 6 iterations...
        let mut s_part = opt::strategy_by_name(name, None).unwrap();
        let mut mm = Minimizer::new(&obj, s_part.as_mut(), &x0, &opts).unwrap();
        for _ in 0..6 {
            match mm.step(&obj) {
                StepOutcome::Stepped(_) => {}
                StepOutcome::Done(stop) => panic!("{name}: stopped early at {stop:?}"),
            }
        }
        let ck = TrainCheckpoint {
            meta: meta_for(&obj, name, n),
            payload: CheckpointPayload::Minimize {
                state: mm.state(),
                strategy_state: mm.strategy_state(),
            },
        };
        // ...serialized, deserialized...
        let bytes = ck.to_bytes();
        drop(mm);
        let back = TrainCheckpoint::from_bytes(&bytes).unwrap();
        back.meta.ensure_matches(&meta_for(&obj, name, n)).unwrap();
        let CheckpointPayload::Minimize { state, strategy_state } = back.payload else {
            panic!("{name}: payload kind changed in roundtrip")
        };
        // ...and resumed on a freshly constructed strategy
        let mut s_res = opt::strategy_by_name(name, None).unwrap();
        let mut mm2 = Minimizer::resume(&obj, s_res.as_mut(), state, &strategy_state, &opts)
            .unwrap();
        mm2.run(&obj);
        let resumed = mm2.into_result();

        assert_eq!(resumed.stop, full.stop, "{name}");
        assert_bitwise_equal(&resumed.x, &full.x, name);
        assert_traces_identical(&resumed.trace, &full.trace, name);
    }
}

#[test]
fn homotopy_resumes_bitwise_identically() {
    // one cache-only strategy (SD: Cholesky rebuilt on restore) and one
    // with evolving memory crossing both checkpoint AND stage
    // boundaries (L-BFGS)
    for &name in &["sd", "lbfgs"] {
        let n = 18;
        let mut rng = nle::data::Rng::new(7);
        let y = Mat::from_fn(n, 4, |_, _| rng.normal());
        let p = nle::affinity::sne_affinities(&y, 5.0);
        let x0 = Mat::from_fn(n, 2, |_, _| 1e-3 * rng.normal());
        let lambdas = log_lambda_schedule(1e-3, 10.0, 6);
        let opts = OptOptions { max_iters: 40, rel_tol: 1e-9, ..Default::default() };
        let mk_obj =
            || NativeObjective::with_affinities(Method::Ee, Attractive::Dense(p.clone()), 1.0, 2);

        let mut obj = mk_obj();
        let mut s_full = opt::strategy_by_name(name, None).unwrap();
        let full = homotopy_resumable(
            &mut obj,
            s_full.as_mut(),
            &x0,
            &lambdas,
            &opts,
            None,
            None,
            None,
        )
        .unwrap();
        let total = full.total_iters();
        assert!(total > 10, "{name}: homotopy too short ({total} iters) to interrupt");

        // capture a mid-path snapshot (global iteration 9 lands inside
        // some stage > 0 for these schedules), round-trip it through
        // the NLEC record, then resume from it
        let mut obj2 = mk_obj();
        let mut s_cap = opt::strategy_by_name(name, None).unwrap();
        let mut snap: Option<HomotopyState> = None;
        let mut cb = |pr: &nle::opt::homotopy::HomotopyProgress<'_, '_>| {
            if snap.is_none() && pr.global_iter == 9 {
                snap = Some(pr.state());
            }
        };
        homotopy_resumable(
            &mut obj2,
            s_cap.as_mut(),
            &x0,
            &lambdas,
            &opts,
            None,
            None,
            Some(&mut cb),
        )
        .unwrap();
        let snap = snap.expect("snapshot at global iteration 9");
        let ck = TrainCheckpoint {
            meta: meta_for(&mk_obj(), name, n),
            payload: CheckpointPayload::Homotopy(snap),
        };
        let back = TrainCheckpoint::from_bytes(&ck.to_bytes()).unwrap();
        let CheckpointPayload::Homotopy(state) = back.payload else {
            panic!("{name}: payload kind changed in roundtrip")
        };

        let mut obj3 = mk_obj();
        let mut s_res = opt::strategy_by_name(name, None).unwrap();
        let resumed = homotopy_resumable(
            &mut obj3,
            s_res.as_mut(),
            &x0,
            &lambdas,
            &opts,
            None,
            Some(state),
            None,
        )
        .unwrap();

        assert_bitwise_equal(&resumed.x, &full.x, name);
        assert_eq!(resumed.stages.len(), full.stages.len(), "{name}");
        for (a, b) in resumed.stages.iter().zip(&full.stages) {
            assert_eq!(a.lambda.to_bits(), b.lambda.to_bits(), "{name}");
            assert_eq!(a.iters, b.iters, "{name}: stage iteration counts differ");
            assert_eq!(a.e.to_bits(), b.e.to_bits(), "{name}: stage energies differ");
            assert_eq!(a.nfev, b.nfev, "{name}: stage nfev differ");
            assert_eq!(a.stop, b.stop, "{name}");
        }
    }
}

/// A coarse-to-fine multigrid job interrupted *after* the stage
/// boundary and resumed from its NLEC record must land on the same
/// bits as the run that was never interrupted. The coarse iteration
/// budget is pinned (`multigrid_coarse_iters`) so the truncated and
/// full runs solve an identical landmark stage; with the checkpoint
/// cadence at 5 and a 12-iteration truncated refinement, the last
/// record lands at refinement iteration 10 — inside stage 1, past the
/// prolongation (which is recomputed, never persisted).
#[test]
fn multigrid_job_resumes_bitwise_across_the_stage_boundary() {
    let data = nle::data::synth::swiss_roll(400, 3, 0.05, 11);
    let mut job = EmbeddingJob::from_data(
        "mg-resume",
        &data.y,
        Method::Ee,
        50.0,
        8.0,
        10,
        IndexSpec::Hnsw { m: 6, ef_construction: 60, ef_search: 40 },
    );
    job.strategy = "sd".to_string();
    job.multigrid = Some(0.05);
    job.multigrid_coarse_iters = Some(8);
    job.opts.max_iters = 30;
    job.opts.rel_tol = 1e-14;
    job.opts.grad_tol = 1e-12;

    let path = std::env::temp_dir().join("nle_mg_resume.nlec");
    let mut partial = job.clone();
    partial.opts.max_iters = 12;
    partial
        .run_resumable(RunControl {
            checkpoint_every: Some(5),
            checkpoint_path: Some(path.clone()),
            ..Default::default()
        })
        .unwrap();
    let ck = TrainCheckpoint::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let CheckpointPayload::Multigrid(st) = &ck.payload else {
        panic!("multigrid job must write a multigrid payload")
    };
    assert_eq!(st.stage, 1, "checkpoint should land in the refinement stage");
    assert_eq!(st.stages.len(), 1, "the completed coarse record rides along");
    let coarse_iters = st.stages[0].iters;

    let resumed =
        job.run_resumable(RunControl { resume: Some(ck), ..Default::default() }).unwrap();
    let full = job.run().unwrap();
    assert_eq!(resumed.iters, full.iters);
    assert_eq!(resumed.stop, full.stop);
    assert_eq!(resumed.e.to_bits(), full.e.to_bits());
    assert_bitwise_equal(&resumed.x, &full.x, "multigrid");
    assert_traces_identical(&resumed.trace, &full.trace, "multigrid");
    // both paths report the identical pinned coarse stage
    let rm = resumed.multigrid.expect("staged run must carry a report");
    let fm = full.multigrid.expect("staged run must carry a report");
    assert_eq!(rm.coarse_n, fm.coarse_n);
    assert_eq!(rm.stages[0].iters, coarse_iters);
    assert_eq!(rm.stages[0].e.to_bits(), fm.stages[0].e.to_bits());
}

#[test]
fn checkpoint_corruption_is_rejected() {
    let n = 16;
    let (obj, x0) = setup(n, 5);
    let opts = OptOptions { max_iters: 10, ..Default::default() };
    let mut s = opt::strategy_by_name("lbfgs", None).unwrap();
    let mut mm = Minimizer::new(&obj, s.as_mut(), &x0, &opts).unwrap();
    for _ in 0..4 {
        let _ = mm.step(&obj);
    }
    let ck = TrainCheckpoint {
        meta: meta_for(&obj, "lbfgs", n),
        payload: CheckpointPayload::Minimize {
            state: mm.state(),
            strategy_state: mm.strategy_state(),
        },
    };
    let bytes = ck.to_bytes();
    // pristine record loads
    assert!(TrainCheckpoint::from_bytes(&bytes).is_ok());
    // bad magic
    let mut bad = bytes.clone();
    bad[0] = b'X';
    assert!(TrainCheckpoint::from_bytes(&bad).is_err());
    // a model record is not a checkpoint
    assert!(TrainCheckpoint::from_bytes(b"NLEM\x01\x00\x00\x00").is_err());
    // unknown version
    let mut bad = bytes.clone();
    bad[4] = 0x7F;
    assert!(TrainCheckpoint::from_bytes(&bad).is_err());
    // truncation at every framing boundary and mid-payload
    for cut in [0, 3, 7, 15, bytes.len() / 3, bytes.len() - 1] {
        assert!(TrainCheckpoint::from_bytes(&bytes[..cut]).is_err(), "cut {cut} must fail");
    }
    // every single flipped payload byte is caught by the checksum
    for off in (16..bytes.len() - 8).step_by(97) {
        let mut bad = bytes.clone();
        bad[off] ^= 0x10;
        assert!(TrainCheckpoint::from_bytes(&bad).is_err(), "flip at {off} must fail");
    }
    // trailing garbage
    let mut bad = bytes.clone();
    bad.push(1);
    assert!(TrainCheckpoint::from_bytes(&bad).is_err());
}

#[test]
fn resume_refuses_wrong_problem() {
    let n = 16;
    let (obj, x0) = setup(n, 6);
    let opts = OptOptions { max_iters: 10, ..Default::default() };
    let mut s = opt::strategy_by_name("sd", None).unwrap();
    let mut mm = Minimizer::new(&obj, s.as_mut(), &x0, &opts).unwrap();
    for _ in 0..3 {
        let _ = mm.step(&obj);
    }
    let meta = meta_for(&obj, "sd", n);
    // strategy mismatch
    let mut other = meta.clone();
    other.strategy = "gd".into();
    assert!(meta.ensure_matches(&other).is_err());
    // lambda mismatch (bitwise)
    let mut other = meta.clone();
    other.lambda = meta.lambda + 1e-12;
    assert!(meta.ensure_matches(&other).is_err());
    // weights mismatch
    let mut other = meta.clone();
    other.weights_fp ^= 1;
    assert!(meta.ensure_matches(&other).is_err());
    // engine / backend mismatch (exact vs Barnes–Hut gradients differ
    // numerically, so a resume across engines must be refused)
    let mut other = meta.clone();
    other.engine = "BarnesHut { theta: 0.5 }".into();
    assert!(meta.ensure_matches(&other).is_err());
    let mut other = meta.clone();
    other.backend = "xla".into();
    assert!(meta.ensure_matches(&other).is_err());
    // sampler seed is identity (different seed = different trajectory);
    // the epoch is state and must NOT be matched
    let mut other = meta.clone();
    other.sampler = Some((1, 0));
    assert!(meta.ensure_matches(&other).is_err());
    let mut a = meta.clone();
    a.sampler = Some((5, 120));
    let mut b = meta.clone();
    b.sampler = Some((5, 0));
    assert!(a.ensure_matches(&b).is_ok());
    // size mismatch is caught by state validation too
    let state = mm.state();
    assert!(state.validate(n + 1, 2).is_err());
    assert!(state.validate(n, 3).is_err());
    assert!(state.validate(n, 2).is_ok());
}
