//! End-to-end pipeline tests: data -> affinities -> objective ->
//! coordinator jobs -> metrics, mirroring what the figure harnesses do
//! at miniature scale.

use std::sync::Arc;
use std::time::Duration;

use nle::coordinator::{run_batch, run_batch_sync, EmbeddingJob, JobEvent};
use nle::data::{coil, mnist_like, synth};
use nle::metrics::quality::label_knn_accuracy;
use nle::objective::{Attractive, Method};

#[test]
fn coil_pipeline_produces_separable_embedding() {
    let ds = coil::generate(&coil::CoilParams {
        objects: 4,
        views: 18,
        ambient_dim: 64,
        ..Default::default()
    });
    let p = nle::affinity::sne_affinities(&ds.y, 8.0);
    let mut job = EmbeddingJob::native(
        "coil-mini",
        Method::Ee,
        100.0,
        Arc::new(Attractive::Dense(p)),
        "sd",
        None,
    );
    job.opts.max_iters = 300;
    let res = job.run().unwrap();
    let acc = label_knn_accuracy(&res.x, &ds.labels, 5);
    assert!(acc > 0.8, "COIL-mini label accuracy {acc}");
}

#[test]
fn mnist_like_sparse_pipeline_runs() {
    let ds = mnist_like::generate(&mnist_like::MnistLikeParams {
        n: 300,
        ambient_dim: 96,
        ..Default::default()
    });
    let p = nle::affinity::sne_affinities_sparse(&ds.y, 10.0, 30);
    let mut job = EmbeddingJob::native(
        "mnist-mini",
        Method::Tsne,
        1.0,
        Arc::new(Attractive::Sparse(p)),
        "sd",
        None,
    );
    job.kappa = Some(7);
    job.opts.max_iters = 150;
    let res = job.run().unwrap();
    assert!(res.e.is_finite());
    let acc = label_knn_accuracy(&res.x, &ds.labels, 5);
    assert!(acc > 0.5, "MNIST-mini label accuracy {acc}");
}

#[test]
fn fig2_style_batch_under_budget() {
    let ds = synth::clusters(60, 3, 12, 12.0, 9);
    let p = Arc::new(Attractive::Dense(nle::affinity::sne_affinities(&ds.y, 8.0)));
    let mut jobs: Vec<EmbeddingJob> = Vec::new();
    for s in ["gd", "fp", "sd"] {
        for seed in 0..3u64 {
            let mut j = EmbeddingJob::native(
                format!("{s}:{seed}"),
                Method::Ssne,
                1.0,
                p.clone(),
                s,
                Some(Duration::from_millis(400)),
            );
            j.init_seed = seed;
            j.opts.max_iters = 100_000;
            j.opts.rel_tol = 1e-15;
            jobs.push(j);
        }
    }
    let t0 = std::time::Instant::now();
    let results = run_batch_sync(jobs, 1);
    assert_eq!(results.len(), 9);
    // sequential budgeted batch: total time ~ 9 * 0.4 s (plus overhead)
    assert!(t0.elapsed() < Duration::from_secs(20));
    for r in results {
        let r = r.unwrap();
        assert!(r.e.is_finite(), "{}", r.name);
    }
}

#[test]
fn batch_events_track_lifecycle() {
    let ds = synth::clusters(30, 2, 8, 10.0, 11);
    let p = Arc::new(Attractive::Dense(nle::affinity::sne_affinities(&ds.y, 6.0)));
    let mut jobs = Vec::new();
    for i in 0..3 {
        let mut j = EmbeddingJob::native(
            format!("ev{i}"),
            Method::Ee,
            5.0,
            p.clone(),
            "fp",
            None,
        );
        j.opts.max_iters = 20;
        jobs.push(j);
    }
    let (tx, rx) = std::sync::mpsc::channel();
    let results = run_batch(jobs, 2, Some(tx));
    assert!(results.iter().all(|r| r.is_ok()));
    let events: Vec<JobEvent> = rx.try_iter().collect();
    let started = events.iter().filter(|e| matches!(e, JobEvent::Started { .. })).count();
    let finished = events.iter().filter(|e| matches!(e, JobEvent::Finished { .. })).count();
    assert_eq!(started, 3);
    assert_eq!(finished, 3);
}

#[test]
fn embedding_csv_roundtrip_through_pipeline() {
    let ds = synth::swiss_roll(50, 3, 0.01, 13);
    let p = Arc::new(Attractive::Dense(nle::affinity::sne_affinities(&ds.y, 8.0)));
    let mut job = EmbeddingJob::native("csv", Method::Ee, 50.0, p, "sd", None);
    job.opts.max_iters = 50;
    let res = job.run().unwrap();
    let path = std::env::temp_dir().join("nle_pipeline_roundtrip.csv");
    nle::data::loader::save_embedding_csv(&path, &res.x, &ds.labels).unwrap();
    let loaded = nle::data::loader::load_csv(&path).unwrap();
    assert_eq!(loaded.y.rows, 50);
    assert!(loaded.y.max_abs_diff(&res.x) < 1e-5);
    std::fs::remove_file(&path).ok();
}
