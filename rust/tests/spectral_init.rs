//! Spectral warm-start pipeline guarantees:
//!
//! * eigenpair parity: the randomized solver ([`nle::linalg::rsvd`]),
//!   the Krylov solver ([`nle::linalg::lanczos`]) and the dense
//!   reference ([`nle::linalg::eig::sym_eig`]) agree on the bottom
//!   eigenspace of a real affinity-graph Laplacian — compared as a
//!   *subspace* (smallest singular value of `V₁ᵀV₂`), never vector by
//!   vector, so sign flips and degenerate-pair mixing cannot fail it;
//! * thread determinism: the parallel symmetric matvec keeps rsvd and
//!   the spectral init bitwise identical across `NLE_THREADS` settings,
//!   verified by re-executing this test binary in pinned subprocesses;
//! * end to end: on a 2k swiss roll the spectral start reaches the
//!   quality bar in fewer optimizer iterations than the random start —
//!   the reason the pipeline exists.

use std::sync::Arc;

use nle::linalg::sparse::SpMat;
use nle::prelude::*;

/// kNN-sparse SNE affinity graph of a swiss roll — the exact operator
/// the production init path feeds to the eigensolvers.
fn affinity_graph(n: usize, seed: u64) -> SpMat {
    let data = nle::data::synth::swiss_roll(n, 3, 0.05, seed);
    nle::affinity::sne_affinities_sparse(&data.y, 10.0, 12)
}

/// Smallest singular value of `V₁ᵀV₂` for two orthonormal bases: 1 iff
/// the spanned subspaces coincide, 0 iff some direction is orthogonal.
fn subspace_agreement(v1: &Mat, v2: &Mat) -> f64 {
    assert_eq!(v1.rows, v2.rows);
    assert_eq!(v1.cols, v2.cols);
    let c = v1.t().matmul(v2);
    let cc = c.t().matmul(&c);
    // singular values of C are the square roots of eig(CᵀC)
    let e = nle::linalg::eig::sym_eig(&cc);
    e.values[0].max(0.0).sqrt()
}

/// Orthonormality witness: `‖VᵀV − I‖_max` must be tiny before a
/// subspace comparison means anything.
fn orthonormality_defect(v: &Mat) -> f64 {
    let g = v.t().matmul(v);
    let mut worst: f64 = 0.0;
    for i in 0..g.rows {
        for j in 0..g.cols {
            let want = if i == j { 1.0 } else { 0.0 };
            worst = worst.max((g.at(i, j) - want).abs());
        }
    }
    worst
}

/// The three eigensolvers must land on the same bottom eigenspace of
/// the production operator (the normalized Laplacian of a real affinity
/// graph). Both iterative solvers are pushed into their *exact* regime
/// — Lanczos with a full Krylov space (m = n) and rsvd with the
/// oversampled basis clamped to n columns, where Rayleigh–Ritz is an
/// exact similarity transform — so the comparison pins the shared
/// algebra (shift, orthonormalization, Rayleigh–Ritz, back-ordering) to
/// float precision. A manifold Laplacian has no spectral gap, so the
/// *approximation* regime is deliberately not asserted here; the rsvd
/// unit tests pin it on gapped spectra where rates are predictable.
#[test]
fn rsvd_lanczos_and_dense_agree_on_the_bottom_eigenspace() {
    let w = affinity_graph(220, 3);
    let lsym = nle::graph::normalized_laplacian_sparse(&w);
    let n = lsym.rows;
    let k = 5;

    let dense = nle::linalg::eig::sym_eig(&lsym.to_dense());
    let dense_v = Mat::from_fn(n, k, |i, j| dense.vectors.at(i, j));

    let lan = nle::linalg::lanczos::smallest_eigs(&lsym, k, Some(n), 7);
    assert_eq!(lan.vectors.cols, k, "Lanczos must find all {k} pairs here");
    // p > n clamps the basis to n columns -> exact Rayleigh-Ritz
    let rs = nle::linalg::rsvd::smallest_eigs(&lsym, k, 2, n, 7);
    assert_eq!(rs.vectors.cols, k);

    for j in 0..k {
        assert!(
            (lan.values[j] - dense.values[j]).abs() < 1e-7,
            "lanczos value {j}: {} vs dense {}",
            lan.values[j],
            dense.values[j]
        );
        assert!(
            (rs.values[j] - dense.values[j]).abs() < 1e-7,
            "rsvd value {j}: {} vs dense {}",
            rs.values[j],
            dense.values[j]
        );
    }
    assert!(orthonormality_defect(&lan.vectors) < 1e-8);
    assert!(orthonormality_defect(&rs.vectors) < 1e-8);
    let a_ld = subspace_agreement(&lan.vectors, &dense_v);
    let a_rd = subspace_agreement(&rs.vectors, &dense_v);
    let a_rl = subspace_agreement(&rs.vectors, &lan.vectors);
    assert!(a_ld > 1.0 - 1e-4, "lanczos/dense subspace agreement {a_ld}");
    assert!(a_rd > 1.0 - 1e-4, "rsvd/dense subspace agreement {a_rd}");
    assert!(a_rl > 1.0 - 1e-4, "rsvd/lanczos subspace agreement {a_rl}");
}

/// FNV-1a over raw f64 bits — order-sensitive, process-portable.
fn fingerprint(values: &[f64], vectors: &Mat) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut mix = |bits: u64| {
        for b in bits.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    };
    for &v in values {
        mix(v.to_bits());
    }
    for &v in &vectors.data {
        mix(v.to_bits());
    }
    h
}

/// The bits whose stability across worker counts is under test: one
/// rsvd eigendecomposition plus one full spectral init, both driven by
/// the parallel symmetric matvec.
fn spectral_fingerprint() -> u64 {
    let w = affinity_graph(250, 11);
    let lsym = nle::graph::normalized_laplacian_sparse(&w);
    let rs = nle::linalg::rsvd::smallest_eigs(&lsym, 4, 4, 8, 5);
    let x0 = nle::init::spectral_init(&w, 2, 1e-4, 9);
    fingerprint(&rs.values, &rs.vectors).rotate_left(17) ^ fingerprint(&[], &x0)
}

/// Bitwise determinism across thread counts: the ordered parallel
/// matvec must make the randomized pipeline independent of the worker
/// count (the thread count is read once per process, so pinned
/// subprocesses are the only way to vary it).
#[test]
fn spectral_init_is_bitwise_identical_across_thread_counts() {
    const CHILD_ENV: &str = "NLE_SI_CHILD";
    if std::env::var(CHILD_ENV).is_ok() {
        println!("SI_FP {:016x}", spectral_fingerprint());
        return;
    }
    let here = spectral_fingerprint();
    assert_eq!(here, spectral_fingerprint(), "same-process rerun must be stable");
    for threads in ["1", "3"] {
        let out = std::process::Command::new(std::env::current_exe().unwrap())
            .args([
                "spectral_init_is_bitwise_identical_across_thread_counts",
                "--exact",
                "--nocapture",
            ])
            .env(CHILD_ENV, "1")
            .env("NLE_THREADS", threads)
            .output()
            .expect("spawning the child test process");
        assert!(out.status.success(), "child with NLE_THREADS={threads} failed");
        let stdout = String::from_utf8_lossy(&out.stdout);
        let fp = stdout
            .lines()
            .find_map(|l| l.strip_prefix("SI_FP "))
            .unwrap_or_else(|| panic!("no fingerprint in child output:\n{stdout}"));
        let fp = u64::from_str_radix(fp.trim(), 16).unwrap();
        assert_eq!(fp, here, "NLE_THREADS={threads} changed the spectral-init bits");
    }
}

/// End to end on a 2k swiss roll: with identical affinities, optimizer
/// and seeds, the spectral start must reach the quality bar (10% of the
/// random baseline's energy drop above the best final energy) in fewer
/// optimizer iterations than the random start.
#[test]
fn spectral_start_beats_random_in_iterations_on_2k_swiss_roll() {
    let n = 2000;
    let data = nle::data::synth::swiss_roll(n, 3, 0.05, 42);
    let wp = Arc::new(Attractive::Sparse(nle::affinity::sne_affinities_sparse(
        &data.y, 15.0, 20,
    )));
    let run = |init: InitSpec| {
        let mut job = EmbeddingJob::native("init-e2e", Method::Ee, 100.0, wp.clone(), "sd", None);
        job.engine = EngineSpec::BarnesHut { theta: 0.5 };
        job.init = init;
        job.opts.max_iters = 80;
        job.run().unwrap()
    };
    let rand = run(InitSpec::Random);
    let spec = run(InitSpec::Spectral { solver: SpectralSolver::default_rsvd() });
    assert!(rand.e.is_finite() && spec.e.is_finite());

    let e0 = rand.trace.first().unwrap().e;
    let e_best = rand.e.min(spec.e);
    let thresh = e_best + 0.10 * (e0 - e_best);
    let to_quality = |trace: &[IterStats]| {
        trace.iter().find(|t| t.e <= thresh).map(|t| t.iter).unwrap_or(usize::MAX)
    };
    let it_rand = to_quality(&rand.trace);
    let it_spec = to_quality(&spec.trace);
    assert!(it_spec < usize::MAX, "spectral run never reached the quality bar");
    assert!(
        it_spec < it_rand,
        "spectral start took {it_spec} iters to quality, random took {it_rand}"
    );
}
