//! Offline **stub** of the `xla` (PJRT wrapper) crate.
//!
//! Mirrors exactly the API surface `nle::runtime` and
//! `nle::objective::xla` use, so the crate builds without the XLA C
//! library. Every entry point that would touch PJRT returns an
//! [`Error`] at runtime; callers already handle those errors (the
//! integration tests skip, the CLI reports "no artifacts"), so the
//! native backend — the default — is unaffected. Swap this path
//! dependency for the real crate to light up the AOT-artifact path.

use std::fmt;
use std::path::Path;

/// Stub error: explains that the real `xla` crate is not linked.
pub struct Error(String);

impl Error {
    fn stub(what: &str) -> Self {
        Error(format!(
            "xla stub: {what} unavailable (offline build links rust/vendor/xla; \
             swap in the real `xla` crate for the PJRT runtime)"
        ))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// A PJRT device handle (never constructed by the stub).
pub struct PjRtDevice;

/// A PJRT client. `cpu()` always fails in the stub, so no other method
/// is reachable on a live value; all still typecheck against the real
/// crate's signatures.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error::stub("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        Err(Error::stub("buffer_from_host_buffer"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub("compile"))
    }
}

/// A device buffer (never constructed by the stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub("to_literal_sync"))
    }
}

/// A loaded executable (never constructed by the stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub("execute_b"))
    }
}

/// Parsed HLO module text.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self> {
        Err(Error::stub("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A host literal (never constructed by the stub).
pub struct Literal;

impl Literal {
    pub fn to_tuple2(self) -> Result<(Literal, Literal)> {
        Err(Error::stub("to_tuple2"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::stub("to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_gracefully() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(format!("{e:?}").contains("xla stub"));
    }
}
