//! Minimal offline stand-in for the `anyhow` crate.
//!
//! Implements exactly the subset this repository uses: the [`Error`]
//! type (constructible from any `std::error::Error` via `?`), the
//! [`Result`] alias, and the `anyhow!` / `bail!` / `ensure!` macros.
//! API-compatible with the real crate for these entry points, so the
//! dependency in `rust/Cargo.toml` can be switched to the crates.io
//! `anyhow` without touching any call site.

use std::error::Error as StdError;
use std::fmt;

/// A string-backed error that records its source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Construct from a message (the `anyhow!` macro's entry point).
    pub fn msg<M: Into<String>>(m: M) -> Self {
        Error { msg: m.into(), source: None }
    }

    /// The root-cause chain, outermost first (diagnostics helper).
    pub fn chain(&self) -> impl Iterator<Item = &(dyn StdError + 'static)> {
        let mut next: Option<&(dyn StdError + 'static)> =
            self.source.as_deref().map(|e| e as &(dyn StdError + 'static));
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source();
            Some(cur)
        })
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        for cause in self.chain() {
            write!(f, "\n\nCaused by:\n    {cause}")?;
        }
        Ok(())
    }
}

// Like the real anyhow: Error deliberately does NOT implement
// std::error::Error, so this blanket conversion (what makes `?` work on
// io::Error etc.) does not collide with core's reflexive From.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string(), source: Some(Box::new(e)) }
    }
}

/// `Result<T, anyhow::Error>` alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    #[test]
    fn macros_and_conversions() {
        fn fails() -> crate::Result<()> {
            crate::ensure!(1 + 1 == 3, "math broke: {}", 42);
            Ok(())
        }
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "math broke: 42");

        fn io_pass_through() -> crate::Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        let e = io_pass_through().unwrap_err();
        assert!(e.chain().count() >= 1);
        assert!(!format!("{e:?}").is_empty());

        fn bails() -> crate::Result<()> {
            crate::bail!("stop");
        }
        assert_eq!(bails().unwrap_err().to_string(), "stop");
    }
}
