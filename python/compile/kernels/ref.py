"""Pure-jnp correctness oracle for the embedding objectives.

This module is the *reference semantics* of the whole stack: the Pallas
kernel (pairwise.py), the L2 jax model (model.py) and the rust native
objective (rust/src/objective/native.rs) are all tested against these
functions.

Conventions (match the paper, Vladymyrov & Carreira-Perpinan, ICML 2012):
  X    : (N, d) low-dimensional coordinates (the paper writes X as d x N;
         we store row-major points, the math is identical).
  Wp   : (N, N) symmetric nonnegative attractive weights, zero diagonal.
         For normalized methods (s-SNE, t-SNE) this is P = (p_nm),
         normalized to sum to 1 over all off-diagonal pairs.
  Wm   : (N, N) symmetric nonnegative repulsive weights (EE only).
  lam  : scalar lambda >= 0.

Objectives (eq. 1 of the paper, E = E+ + lam * E-):
  spectral : E = sum_nm Wp_nm ||x_n - x_m||^2
  EE       : E = sum_nm Wp_nm ||x_n - x_m||^2
                 + lam * sum_nm Wm_nm exp(-||x_n - x_m||^2)
  s-SNE    : E = sum_nm P_nm ||x_n - x_m||^2
                 + lam * log sum_nm exp(-||x_n - x_m||^2)
  t-SNE    : E = sum_nm P_nm log(1 + ||x_n - x_m||^2)
                 + lam * log sum_nm 1/(1 + ||x_n - x_m||^2)

Gradients in Laplacian form (eqs. 2-3): grad E = 4 X L with L = D - W and
the method-specific weights W given in the paper (and DESIGN.md section 1).
With X stored (N, d) this reads G = 4 (D - W) X.
"""

import jax.numpy as jnp

__all__ = [
    "sqdist",
    "gauss_kernel",
    "student_kernel",
    "laplacian_apply",
    "spectral_obj",
    "ee_obj",
    "ssne_obj",
    "tsne_obj",
    "objective",
]


def sqdist(X):
    """Pairwise squared Euclidean distances, (N, N), exact zero diagonal."""
    n2 = jnp.sum(X * X, axis=1)
    d2 = n2[:, None] + n2[None, :] - 2.0 * (X @ X.T)
    d2 = jnp.maximum(d2, 0.0)
    return d2 * (1.0 - jnp.eye(X.shape[0], dtype=X.dtype))


def gauss_kernel(d2):
    """K(t) = exp(-t), zeroed on the diagonal (q_nn = 0 in the paper)."""
    n = d2.shape[0]
    return jnp.exp(-d2) * (1.0 - jnp.eye(n, dtype=d2.dtype))


def student_kernel(d2):
    """K(t) = 1/(1+t), zeroed on the diagonal."""
    n = d2.shape[0]
    return (1.0 / (1.0 + d2)) * (1.0 - jnp.eye(n, dtype=d2.dtype))


def laplacian_apply(W, X):
    """(D - W) X with D = diag(W 1). The 4 X L gradient core."""
    deg = jnp.sum(W, axis=1)
    return deg[:, None] * X - W @ X


def spectral_obj(X, Wp):
    """Spectral/Laplacian-eigenmaps E+ term: E, grad (lam = 0 case)."""
    d2 = sqdist(X)
    e = jnp.sum(Wp * d2)
    g = 4.0 * laplacian_apply(Wp, X)
    return e, g


def ee_obj(X, Wp, Wm, lam):
    """Elastic embedding (Carreira-Perpinan 2010). Returns (E, grad)."""
    d2 = sqdist(X)
    kneg = gauss_kernel(d2)
    e = jnp.sum(Wp * d2) + lam * jnp.sum(Wm * kneg)
    w = Wp - lam * Wm * kneg
    g = 4.0 * laplacian_apply(w, X)
    return e, g


def ssne_obj(X, P, lam):
    """Symmetric SNE (Cook et al. 2007), Gaussian kernel. Returns (E, grad).

    E+ = -sum P log K = sum P d2 (when sum P = 1)
    E- = log sum_nm exp(-d2_nm), n != m.
    Gradient weights: w_nm = p_nm - lam q_nm.
    """
    d2 = sqdist(X)
    k = gauss_kernel(d2)
    s = jnp.sum(k)
    q = k / s
    e = jnp.sum(P * d2) + lam * jnp.log(s)
    w = P - lam * q
    g = 4.0 * laplacian_apply(w, X)
    return e, g


def tsne_obj(X, P, lam):
    """t-SNE (van der Maaten & Hinton 2008), Student kernel. (E, grad).

    E+ = -sum P log K = sum P log(1 + d2); E- = log sum K.
    Gradient weights: w_nm = (p_nm - lam q_nm) K_nm.
    """
    d2 = sqdist(X)
    k = student_kernel(d2)
    s = jnp.sum(k)
    q = k / s
    e = jnp.sum(P * jnp.log1p(d2)) + lam * jnp.log(s)
    w = (P - lam * q) * k
    g = 4.0 * laplacian_apply(w, X)
    return e, g


def objective(method, X, Wp, Wm=None, lam=1.0):
    """Dispatch on method name. Returns (E, grad)."""
    if method == "spectral":
        return spectral_obj(X, Wp)
    if method == "ee":
        return ee_obj(X, Wp, Wm, lam)
    if method == "ssne":
        return ssne_obj(X, Wp, lam)
    if method == "tsne":
        return tsne_obj(X, Wp, lam)
    raise ValueError(f"unknown method {method!r}")
