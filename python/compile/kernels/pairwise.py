"""L1: Pallas pairwise-affinity kernel — the O(N^2 d) compute hot-spot.

Every objective in the family (spectral, EE, s-SNE, t-SNE) spends its time
computing pairwise squared distances and a decreasing kernel K of them
(paper section 1). This kernel fuses both in one tiled pass:

    (sqd, K)[i, j] = (||x_i - x_j||^2, K(||x_i - x_j||^2)),   K_ii = 0

TPU mapping (DESIGN.md section "Hardware-Adaptation"): the grid tiles the
(N, N) output into (BN, BM) blocks; each step streams two row-blocks of X
from HBM into VMEM, computes the cross term as a (BN, d) x (d, BM) matmul
on the MXU, the rank-1 norm corrections and the transcendental K on the
VPU, and writes the two output tiles back. Three tiles of d<=64 f32 rows
fit VMEM with two orders of magnitude to spare, so the schedule is purely
bandwidth-bound in HBM.

interpret=True always: the CPU PJRT client cannot execute Mosaic
custom-calls, so we lower the interpret path to plain HLO (see
/opt/xla-example/README.md). Correctness vs kernels/ref.py is enforced by
python/tests/test_kernel.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["pairwise", "block_size"]

_KINDS = ("gauss", "student")


def block_size(n, cap=128):
    """Largest power of two <= cap that divides n (grid must tile N exactly)."""
    b = 1
    while b * 2 <= cap and n % (b * 2) == 0:
        b *= 2
    return b


def _pairwise_kernel(x_ref, y_ref, d2_ref, k_ref, *, kind, bn, bm):
    """One (BN, BM) tile: squared distances + kernel, diagonal zeroed."""
    x = x_ref[...]  # (BN, d) rows n-block
    y = y_ref[...]  # (BM, d) rows m-block
    xn = jnp.sum(x * x, axis=1)  # (BN,)
    yn = jnp.sum(y * y, axis=1)  # (BM,)
    # MXU: the (BN, d) x (d, BM) cross term dominates the FLOPs.
    cross = jax.lax.dot_general(
        x, y, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    d2 = xn[:, None] + yn[None, :] - 2.0 * cross
    d2 = jnp.maximum(d2, 0.0)
    # Global diagonal mask: tile (i, j) holds rows i*BN.. and cols j*BM..
    i = pl.program_id(0)
    j = pl.program_id(1)
    rows = i * bn + jax.lax.broadcasted_iota(jnp.int32, (bn, bm), 0)
    cols = j * bm + jax.lax.broadcasted_iota(jnp.int32, (bn, bm), 1)
    offdiag = (rows != cols).astype(d2.dtype)
    d2 = d2 * offdiag
    if kind == "gauss":
        k = jnp.exp(-d2)
    elif kind == "student":
        k = 1.0 / (1.0 + d2)
    else:  # pragma: no cover - guarded by pairwise()
        raise ValueError(kind)
    d2_ref[...] = d2
    k_ref[...] = k * offdiag


@functools.partial(jax.jit, static_argnames=("kind",))
def pairwise(x, kind="gauss"):
    """Fused pairwise (squared-distance, kernel) matrices for (N, d) input.

    Returns (d2, K), both (N, N) f32, K with zero diagonal. `kind` selects
    the paper's two kernels: "gauss" K(t)=exp(-t) (SNE, EE) or "student"
    K(t)=1/(1+t) (t-SNE).
    """
    if kind not in _KINDS:
        raise ValueError(f"kind must be one of {_KINDS}, got {kind!r}")
    n, d = x.shape
    bn = bm = block_size(n)
    grid = (n // bn, n // bm)
    kernel = functools.partial(_pairwise_kernel, kind=kind, bn=bn, bm=bm)
    out_shape = [
        jax.ShapeDtypeStruct((n, n), jnp.float32),
        jax.ShapeDtypeStruct((n, n), jnp.float32),
    ]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
            pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
        ],
        out_shape=out_shape,
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, x)
