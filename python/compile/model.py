"""L2: the embedding objectives as jax functions, calling the L1 kernel.

These are the computations that get AOT-lowered to HLO text by aot.py and
executed from the rust hot path (rust/src/objective/xla.rs). Each function
returns the tuple (E, G): the scalar objective and its (N, d) gradient, in
the Laplacian form of the paper (grad E = 4 X L, eqs. 2-3), built on top of
the fused pairwise-affinity Pallas kernel.

lambda is a runtime input (f32 scalar), NOT baked into the artifact, so a
single artifact serves the whole homotopy path lambda in [1e-4, 1e2].

Gradients are analytic (the paper gives the Laplacian weights in closed
form); we deliberately do not autodiff through pallas_call. Parity with
jax.grad of the ref.py oracle is asserted in python/tests/test_model.py.
"""

import jax.numpy as jnp

from .kernels.pairwise import pairwise

__all__ = [
    "spectral_value_grad",
    "ee_value_grad",
    "ssne_value_grad",
    "tsne_value_grad",
    "MODELS",
]


def _lap_apply(w, x):
    """(D - W) X, the 4 X L gradient core (D = diag of row sums)."""
    deg = jnp.sum(w, axis=1)
    return deg[:, None] * x - w @ x


def spectral_value_grad(x, wp):
    """Spectral E+ only (lam = 0): E = sum Wp d2, G = 4 L+ X."""
    d2, _ = pairwise(x, "gauss")
    e = jnp.sum(wp * d2)
    g = 4.0 * _lap_apply(wp, x)
    return e, g


def ee_value_grad(x, wp, wm, lam):
    """Elastic embedding: attractive quadratic + Gaussian repulsion."""
    d2, k = pairwise(x, "gauss")
    e = jnp.sum(wp * d2) + lam * jnp.sum(wm * k)
    w = wp - lam * wm * k
    g = 4.0 * _lap_apply(w, x)
    return e, g


def ssne_value_grad(x, p, lam):
    """Symmetric SNE: Gaussian kernel, normalized over all pairs."""
    d2, k = pairwise(x, "gauss")
    s = jnp.sum(k)
    q = k / s
    e = jnp.sum(p * d2) + lam * jnp.log(s)
    w = p - lam * q
    g = 4.0 * _lap_apply(w, x)
    return e, g


def tsne_value_grad(x, p, lam):
    """t-SNE: Student kernel, normalized; weights (p - lam q) K."""
    d2, k = pairwise(x, "student")
    s = jnp.sum(k)
    q = k / s
    e = jnp.sum(p * jnp.log1p(d2)) + lam * jnp.log(s)
    w = (p - lam * q) * k
    g = 4.0 * _lap_apply(w, x)
    return e, g


# name -> (fn, input shape builder). The builder maps (N, d) to the example
# shapes used for lowering; order defines the rust call ABI:
#   spectral: (X[N,d], Wp[N,N])                 -> (E[], G[N,d])
#   ee      : (X[N,d], Wp[N,N], Wm[N,N], lam[]) -> (E[], G[N,d])
#   ssne    : (X[N,d], P[N,N], lam[])           -> (E[], G[N,d])
#   tsne    : (X[N,d], P[N,N], lam[])           -> (E[], G[N,d])
MODELS = {
    "spectral": (spectral_value_grad, lambda n, d: [(n, d), (n, n)]),
    "ee": (ee_value_grad, lambda n, d: [(n, d), (n, n), (n, n), ()]),
    "ssne": (ssne_value_grad, lambda n, d: [(n, d), (n, n), ()]),
    "tsne": (tsne_value_grad, lambda n, d: [(n, d), (n, n), ()]),
}
