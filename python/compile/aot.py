"""AOT compile path: lower the L2 objectives to HLO *text* artifacts.

Run once by `make artifacts`; the rust runtime
(rust/src/runtime/mod.rs) then loads `artifacts/<name>.hlo.txt` with
`HloModuleProto::from_text_file`, compiles on the PJRT CPU client and
executes — python never appears on the request path.

HLO TEXT, not `.serialize()`: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (the version the published
`xla = 0.1.6` crate binds) rejects with `proto.id() <= INT_MAX`. The text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Usage (from python/):
    python -m compile.aot --out ../artifacts \
        [--methods ee,ssne,tsne,spectral] [--sizes 128,256,720] [--dim 2]

Emits one artifact per (method, N, d) plus manifest.json describing the
call ABI for the rust side.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import MODELS

DEFAULT_SIZES = (128, 256, 720)
DEFAULT_METHODS = ("spectral", "ee", "ssne", "tsne")


def to_hlo_text(lowered):
    """jax lowering -> XlaComputation -> HLO text (return_tuple ABI)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(method, n, d):
    """Lower one (method, N, d) instance; returns (hlo_text, input shapes)."""
    fn, shapes_of = MODELS[method]
    shapes = shapes_of(n, d)
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    lowered = jax.jit(fn).lower(*specs)
    return to_hlo_text(lowered), shapes


def build(out_dir, methods, sizes, dim):
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"dim": dim, "artifacts": []}
    for method in methods:
        for n in sizes:
            name = f"{method}_{n}x{dim}"
            path = os.path.join(out_dir, f"{name}.hlo.txt")
            text, shapes = lower_one(method, n, dim)
            with open(path, "w") as f:
                f.write(text)
            manifest["artifacts"].append(
                {
                    "name": name,
                    "method": method,
                    "n": n,
                    "d": dim,
                    "file": os.path.basename(path),
                    "inputs": [list(s) for s in shapes],
                    "outputs": [[], [n, dim]],
                }
            )
            print(f"  wrote {path} ({len(text) / 1e6:.2f} MB)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    # line-based manifest for the rust loader (no JSON dependency there):
    #   name method n d file
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("# name method n d file\n")
        for a in manifest["artifacts"]:
            f.write(f"{a['name']} {a['method']} {a['n']} {a['d']} {a['file']}\n")
    print(f"manifest: {len(manifest['artifacts'])} artifacts -> {out_dir}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--methods", default=",".join(DEFAULT_METHODS))
    ap.add_argument("--sizes", default=",".join(map(str, DEFAULT_SIZES)))
    ap.add_argument("--dim", type=int, default=2)
    args = ap.parse_args()
    methods = [m for m in args.methods.split(",") if m]
    for m in methods:
        if m not in MODELS:
            raise SystemExit(f"unknown method {m!r}; have {sorted(MODELS)}")
    sizes = [int(s) for s in args.sizes.split(",") if s]
    build(args.out, methods, sizes, args.dim)


if __name__ == "__main__":
    main()
