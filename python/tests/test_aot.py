"""AOT path: HLO-text artifacts round-trip and match the jitted model.

Lowers each model to HLO text (exactly what `make artifacts` ships to
rust), re-parses it with the in-process XLA client, executes, and checks
numeric parity with the direct jax call. This is the strongest guarantee
we can give on the python side that the rust runtime sees correct
computations.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model

N, D = 32, 2


def _inputs(method, n=N, d=D, seed=0):
    rng = np.random.RandomState(seed)
    x = rng.randn(n, d).astype(np.float32)
    w = rng.rand(n, n).astype(np.float32)
    w = (w + w.T) / 2
    np.fill_diagonal(w, 0)
    p = (w / w.sum()).astype(np.float32)
    wm = rng.rand(n, n).astype(np.float32)
    wm = (wm + wm.T) / 2
    np.fill_diagonal(wm, 0)
    lam = np.float32(1.5)
    if method == "spectral":
        return [x, w]
    if method == "ee":
        return [x, w, wm, lam]
    return [x, p, lam]


def _run_hlo_text(text, args):
    """Parse HLO text and execute on the in-process CPU client.

    Mirrors the rust runtime path: HLO text -> HloModule (ids reassigned by
    the text parser) -> compile -> execute. jaxlib's client.compile only
    accepts MLIR modules, so we convert the computation back to MLIR first.
    """
    import jax._src.compiler as jc
    from jax._src.interpreters import mlir as jmlir
    from jax._src.lib.mlir import ir

    backend = jax.devices("cpu")[0].client
    mod = xc._xla.hlo_module_from_text(text)
    comp = xc._xla.XlaComputation(mod.as_serialized_hlo_module_proto())
    mlir_str = xc._xla.mlir.xla_computation_to_mlir_module(comp)
    with jmlir.make_ir_context():
        module = ir.Module.parse(mlir_str)
        opts = jc.get_compile_options(1, 1)
        devs = xc._xla.DeviceList(tuple(backend.local_devices()))
        exe = jc.backend_compile_and_load(backend, module, devs, opts, [])
    bufs = [backend.buffer_from_pyval(a) for a in args]
    out = exe.execute(bufs)
    return [np.asarray(o) for o in out]


@pytest.mark.parametrize("method", ["spectral", "ee", "ssne", "tsne"])
def test_hlo_text_parity(method):
    text, shapes = aot.lower_one(method, N, D)
    assert "ENTRY" in text
    args = _inputs(method)
    assert [list(np.shape(a)) for a in args] == [list(s) for s in shapes]
    fn = model.MODELS[method][0]
    e_ref, g_ref = fn(*[jnp.asarray(a) for a in args])
    try:
        outs = _run_hlo_text(text, args)
    except Exception as exc:  # pragma: no cover - API drift across jax vers
        pytest.skip(f"in-process HLO re-execution unavailable: {exc}")
    # return_tuple=True: outputs arrive as flat list [E, G]
    flat = []
    for o in outs:
        flat.extend(o if isinstance(o, (list, tuple)) else [o])
    e_hlo, g_hlo = flat[0], flat[1]
    np.testing.assert_allclose(e_hlo, np.asarray(e_ref), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(g_hlo, np.asarray(g_ref), rtol=1e-4, atol=1e-5)


def test_build_writes_manifest(tmp_path):
    aot.build(str(tmp_path), ["ee"], [16], 2)
    man = json.loads((tmp_path / "manifest.json").read_text())
    assert man["dim"] == 2
    (art,) = man["artifacts"]
    assert art["method"] == "ee" and art["n"] == 16
    assert os.path.exists(tmp_path / art["file"])
    text = (tmp_path / art["file"]).read_text()
    assert "ENTRY" in text and "f32[16,2]" in text


def test_lowered_hlo_mentions_shapes():
    text, _ = aot.lower_one("tsne", 16, 2)
    assert "f32[16,2]" in text and "f32[16,16]" in text
