"""L2 correctness: model.py objectives vs the ref.py oracle and autodiff.

Three layers of checking:
  1. model.py (Pallas-backed) == ref.py (pure jnp) for E and G;
  2. ref.py's analytic Laplacian-form gradient == jax.grad of the ref energy
     (validates the paper's eqs. 2-3 as implemented);
  3. finite differences on the energy.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)  # for finite-difference checks

from compile import model
from compile.kernels import ref

N, D = 48, 2


def _data(n=N, d=D, seed=0):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(n, d).astype(np.float32))
    wp = rng.rand(n, n).astype(np.float32)
    wp = (wp + wp.T) / 2.0
    np.fill_diagonal(wp, 0.0)
    p = jnp.asarray(wp / wp.sum())
    wm = rng.rand(n, n).astype(np.float32)
    wm = (wm + wm.T) / 2.0
    np.fill_diagonal(wm, 0.0)
    return x, jnp.asarray(wp), p, jnp.asarray(wm)


def _energy_only(method, x, wp, wm, lam):
    e, _ = ref.objective(method, x, wp, wm, lam)
    return e


CASES = [
    ("spectral", 0.0),
    ("ee", 0.5),
    ("ee", 100.0),
    ("ssne", 1.0),
    ("ssne", 0.3),
    ("tsne", 1.0),
    ("tsne", 2.5),
]


@pytest.mark.parametrize("method,lam", CASES)
def test_model_matches_ref(method, lam):
    x, wp, p, wm = _data()
    if method == "spectral":
        e_m, g_m = model.spectral_value_grad(x, wp)
        e_r, g_r = ref.spectral_obj(x, wp)
    elif method == "ee":
        e_m, g_m = model.ee_value_grad(x, wp, wm, lam)
        e_r, g_r = ref.ee_obj(x, wp, wm, lam)
    elif method == "ssne":
        e_m, g_m = model.ssne_value_grad(x, p, lam)
        e_r, g_r = ref.ssne_obj(x, p, lam)
    else:
        e_m, g_m = model.tsne_value_grad(x, p, lam)
        e_r, g_r = ref.tsne_obj(x, p, lam)
    np.testing.assert_allclose(e_m, e_r, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(g_m, g_r, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("method,lam", CASES)
def test_laplacian_gradient_equals_autodiff(method, lam):
    """The paper's closed-form 4 X L gradient == jax.grad of the energy."""
    x, wp, p, wm = _data(n=32, seed=1)
    w_attr = p if method in ("ssne", "tsne") else wp
    _, g_analytic = ref.objective(method, x, w_attr, wm, lam)
    g_auto = jax.grad(
        lambda xx: _energy_only(method, xx, w_attr, wm, lam)
    )(x)
    np.testing.assert_allclose(g_analytic, g_auto, rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("method,lam", [("ee", 10.0), ("ssne", 1.0), ("tsne", 1.0)])
def test_gradient_finite_differences(method, lam):
    x, wp, p, wm = _data(n=16, seed=2)
    x64 = x.astype(jnp.float64)
    w_attr = (p if method in ("ssne", "tsne") else wp).astype(jnp.float64)
    wm64 = wm.astype(jnp.float64)
    _, g = ref.objective(method, x64, w_attr, wm64, lam)
    g = np.asarray(g)
    eps = 1e-5
    rng = np.random.RandomState(3)
    for _ in range(6):
        i, j = rng.randint(0, 16), rng.randint(0, 2)
        pert = np.zeros((16, 2))
        pert[i, j] = eps
        ep = _energy_only(method, x64 + pert, w_attr, wm64, lam)
        em = _energy_only(method, x64 - pert, w_attr, wm64, lam)
        fd = float((ep - em) / (2 * eps))
        assert fd == pytest.approx(g[i, j], rel=2e-3, abs=1e-5)


def test_spectral_is_ee_lambda_zero():
    x, wp, _, wm = _data(seed=4)
    e_s, g_s = ref.spectral_obj(x, wp)
    e_e, g_e = ref.ee_obj(x, wp, wm, 0.0)
    np.testing.assert_allclose(e_s, e_e, rtol=1e-6)
    np.testing.assert_allclose(g_s, g_e, rtol=1e-6)


def test_gradient_zero_at_coincident_spectral():
    # All points coincident: spectral E = 0, gradient = 0 (global min).
    x = jnp.zeros((12, 2), jnp.float32)
    _, wp, _, _ = _data(n=12, seed=5)
    e, g = ref.spectral_obj(x, wp)
    assert float(e) == 0.0
    np.testing.assert_array_equal(np.asarray(g), np.zeros((12, 2)))


def test_shift_invariance():
    """E(X + c) = E(X): both terms depend only on differences (paper sec 1)."""
    x, wp, p, wm = _data(seed=6)
    shift = jnp.asarray([[10.0, -3.0]], jnp.float32)
    for method, lam in CASES:
        w_attr = p if method in ("ssne", "tsne") else wp
        e0, _ = ref.objective(method, x, w_attr, wm, lam)
        e1, _ = ref.objective(method, x + shift, w_attr, wm, lam)
        np.testing.assert_allclose(e0, e1, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(min_value=4, max_value=64),
    lam=st.sampled_from([0.0, 0.1, 1.0, 50.0]),
    method=st.sampled_from(["ee", "ssne", "tsne"]),
    seed=st.integers(min_value=0, max_value=1000),
)
def test_model_ref_parity_hypothesis(n, lam, method, seed):
    x, wp, p, wm = _data(n=n, seed=seed)
    if method == "ee":
        e_m, g_m = model.ee_value_grad(x, wp, wm, lam)
        e_r, g_r = ref.ee_obj(x, wp, wm, lam)
    elif method == "ssne":
        e_m, g_m = model.ssne_value_grad(x, p, lam)
        e_r, g_r = ref.ssne_obj(x, p, lam)
    else:
        e_m, g_m = model.tsne_value_grad(x, p, lam)
        e_r, g_r = ref.tsne_obj(x, p, lam)
    np.testing.assert_allclose(e_m, e_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(g_m, g_r, rtol=1e-3, atol=1e-4)
