"""L1 correctness: the Pallas pairwise kernel vs the pure-jnp oracle.

This is the CORE correctness signal for the compute hot-spot: everything
the rust binary executes flows through this kernel.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import pairwise as pw
from compile.kernels import ref

RTOL = 1e-5
ATOL = 1e-5


def _rand_x(n, d, seed=0, scale=1.0):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randn(n, d).astype(np.float32) * scale)


# ---------------------------------------------------------------- block_size


def test_block_size_divides():
    for n in (1, 2, 48, 127, 128, 720, 2000):
        b = pw.block_size(n)
        assert n % b == 0
        assert b <= 128


def test_block_size_prefers_large():
    assert pw.block_size(720) == 16
    assert pw.block_size(1024) == 128
    assert pw.block_size(128) == 128


# ------------------------------------------------------------------- kernels


@pytest.mark.parametrize("kind", ["gauss", "student"])
@pytest.mark.parametrize("n,d", [(8, 2), (48, 2), (64, 3), (33, 2), (128, 4)])
def test_pairwise_matches_ref(kind, n, d):
    x = _rand_x(n, d, seed=n + d)
    d2, k = pw.pairwise(x, kind)
    d2_ref = ref.sqdist(x)
    k_ref = ref.gauss_kernel(d2_ref) if kind == "gauss" else ref.student_kernel(d2_ref)
    np.testing.assert_allclose(d2, d2_ref, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(k, k_ref, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("kind", ["gauss", "student"])
def test_zero_diagonal(kind):
    x = _rand_x(32, 2)
    d2, k = pw.pairwise(x, kind)
    np.testing.assert_array_equal(np.diag(np.asarray(d2)), np.zeros(32))
    np.testing.assert_array_equal(np.diag(np.asarray(k)), np.zeros(32))


@pytest.mark.parametrize("kind", ["gauss", "student"])
def test_symmetry(kind):
    x = _rand_x(40, 2, seed=7)
    d2, k = pw.pairwise(x, kind)
    np.testing.assert_allclose(d2, jnp.asarray(d2).T, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(k, jnp.asarray(k).T, rtol=RTOL, atol=ATOL)


def test_nonnegative_distances():
    # coincident points: d2 exactly 0, gauss k exactly 1 off-diagonal
    x = jnp.zeros((16, 2), jnp.float32)
    d2, k = pw.pairwise(x, "gauss")
    np.testing.assert_array_equal(np.asarray(d2), np.zeros((16, 16)))
    expected = 1.0 - np.eye(16, dtype=np.float32)
    np.testing.assert_array_equal(np.asarray(k), expected)


def test_student_bounds():
    x = _rand_x(24, 2, seed=3, scale=10.0)
    _, k = pw.pairwise(x, "student")
    k = np.asarray(k)
    assert (k >= 0).all() and (k <= 1).all()


def test_known_values_two_points():
    x = jnp.asarray([[0.0, 0.0], [3.0, 4.0]], jnp.float32)
    d2, kg = pw.pairwise(x, "gauss")
    assert float(d2[0, 1]) == pytest.approx(25.0, rel=1e-6)
    assert float(kg[0, 1]) == pytest.approx(np.exp(-25.0), rel=1e-5, abs=1e-12)
    _, ks = pw.pairwise(x, "student")
    assert float(ks[0, 1]) == pytest.approx(1.0 / 26.0, rel=1e-6)


def test_rejects_unknown_kind():
    with pytest.raises(ValueError):
        pw.pairwise(_rand_x(8, 2), "epanechnikov-typo")


# --------------------------------------------------------- hypothesis sweeps


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=2, max_value=96),
    d=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    scale=st.sampled_from([0.01, 1.0, 100.0]),
    kind=st.sampled_from(["gauss", "student"]),
)
def test_pairwise_hypothesis(n, d, seed, scale, kind):
    x = _rand_x(n, d, seed=seed, scale=scale)
    d2, k = pw.pairwise(x, kind)
    d2_ref = ref.sqdist(x)
    k_ref = ref.gauss_kernel(d2_ref) if kind == "gauss" else ref.student_kernel(d2_ref)
    # scale-aware tolerance: f32 cancellation in ||x||^2+||y||^2-2x.y grows
    # like scale^2, and the blocked (pallas) and full (jnp) contractions
    # accumulate in different orders.
    # Cancellation error is ~ ||x||^2_max * eps_f32, absolute, and since
    # |dK/dt| <= 1 for both kernels it propagates to K at most 1:1.
    n2max = float(jnp.max(jnp.sum(x * x, axis=1)))
    tol = max(1e-5, 4.0 * n2max * np.finfo(np.float32).eps)
    np.testing.assert_allclose(d2, d2_ref, rtol=1e-3, atol=tol)
    np.testing.assert_allclose(k, k_ref, rtol=1e-3, atol=max(1e-5, tol))
