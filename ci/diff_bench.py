#!/usr/bin/env python3
"""Diff fresh BENCH_*.json results against the committed baselines
under results/baselines/.

Default mode is report-only: prints every numeric field that moved, as
a relative delta, and never fails the build — CI runners are noisy
shared machines, so perf deltas are for humans to read in the job log
and judge on trend.

With --max-regress <pct> the diff becomes a gate: any *direction-aware*
metric that regresses by more than <pct> percent fails the run (exit
1). Direction is inferred from the field name — wall-clock-ish fields
(`*_s`, `*_ms`, `*time*`, `*latency*`, `p50`/`p99`) must not grow,
throughput-ish fields (`*rps*`, `*per_sec*`, `*recall*`, `*speedup*`)
must not shrink; everything else stays report-only (iteration counts
and energies move for legitimate reasons). An empty results/baselines/
is a silent pass either way, so the gate is safe to wire in before any
baseline is committed. Refresh the committed numbers with
`ci/perf_smoke.sh --baseline` (see results/baselines/README.md).
"""

import json
import pathlib
import sys

LOWER_IS_BETTER = ("_s", "_ms", "_secs", "_seconds")
LOWER_SUBSTRINGS = ("time", "latency", "p50", "p99")
HIGHER_SUBSTRINGS = ("rps", "per_sec", "recall", "speedup")


def direction(path):
    """-1 if the metric should not grow, +1 if it should not shrink,
    0 if it carries no perf direction (report-only)."""
    leaf = path.rsplit(".", 1)[-1].split("[")[0]
    if leaf.endswith(LOWER_IS_BETTER) or any(s in leaf for s in LOWER_SUBSTRINGS):
        return -1
    if any(s in leaf for s in HIGHER_SUBSTRINGS):
        return +1
    return 0


def numbers(prefix, obj, out):
    """Flatten every numeric leaf into out, keyed by its JSON path."""
    if isinstance(obj, dict):
        for key, val in obj.items():
            numbers(f"{prefix}.{key}" if prefix else key, val, out)
    elif isinstance(obj, list):
        for i, val in enumerate(obj):
            numbers(f"{prefix}[{i}]", val, out)
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        out[prefix] = float(obj)


def main():
    max_regress = None
    argv = sys.argv[1:]
    if argv and argv[0] == "--max-regress":
        if len(argv) < 2:
            print("diff_bench: --max-regress needs a percentage", file=sys.stderr)
            return 2
        try:
            max_regress = float(argv[1])
        except ValueError:
            print(f"diff_bench: bad --max-regress value {argv[1]!r}", file=sys.stderr)
            return 2
        if max_regress <= 0:
            print("diff_bench: --max-regress must be positive", file=sys.stderr)
            return 2

    root = pathlib.Path(__file__).resolve().parent.parent
    fresh_dir = root / "results"
    base_dir = fresh_dir / "baselines"
    baselines = sorted(base_dir.glob("BENCH_*.json")) if base_dir.is_dir() else []
    if not baselines:
        print("diff_bench: no committed BENCH_*.json under results/baselines/ — skipping")
        print("            (capture some with: ci/perf_smoke.sh --baseline)")
        return 0

    breaches = []
    for base in baselines:
        fresh = fresh_dir / base.name
        print(f"== {base.name} (fresh vs committed baseline) ==")
        if not fresh.is_file():
            print("  no fresh result in this run")
            continue
        old, new = {}, {}
        numbers("", json.loads(base.read_text()), old)
        numbers("", json.loads(fresh.read_text()), new)
        moved = 0
        for key in sorted(old):
            if key not in new:
                print(f"  {key}: {old[key]:g} -> (gone)")
                moved += 1
            elif new[key] != old[key]:
                if old[key] != 0:
                    rel = 100.0 * (new[key] - old[key]) / abs(old[key])
                    sign = direction(key)
                    gated = max_regress is not None and sign != 0
                    worse = sign * rel < -max_regress if gated else False
                    tag = " REGRESSION" if worse else ""
                    print(f"  {key}: {old[key]:g} -> {new[key]:g} ({rel:+.1f}%){tag}")
                    if worse:
                        breaches.append(f"{base.name}:{key} ({rel:+.1f}%)")
                else:
                    print(f"  {key}: {old[key]:g} -> {new[key]:g}")
                moved += 1
        for key in sorted(set(new) - set(old)):
            print(f"  {key}: (new) {new[key]:g}")
            moved += 1
        if moved == 0:
            print("  identical")

    if max_regress is None:
        print("diff_bench: report only — baselines never gate the build")
        return 0
    if breaches:
        print(f"diff_bench: {len(breaches)} metric(s) regressed past {max_regress:g}%:")
        for b in breaches:
            print(f"  {b}")
        return 1
    print(f"diff_bench: gate passed — no directional metric regressed past {max_regress:g}%")
    return 0


if __name__ == "__main__":
    sys.exit(main())
