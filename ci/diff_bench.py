#!/usr/bin/env python3
"""Report-only diff of fresh BENCH_*.json results against the committed
baselines under results/baselines/.

Prints every numeric field that moved, as a relative delta. Never fails
the build: CI runners are noisy shared machines, so perf deltas are for
humans to read in the job log and judge on trend, not a gate. Refresh
the committed numbers with `ci/perf_smoke.sh --baseline` (see
results/baselines/README.md).
"""

import json
import pathlib
import sys


def numbers(prefix, obj, out):
    """Flatten every numeric leaf into out, keyed by its JSON path."""
    if isinstance(obj, dict):
        for key, val in obj.items():
            numbers(f"{prefix}.{key}" if prefix else key, val, out)
    elif isinstance(obj, list):
        for i, val in enumerate(obj):
            numbers(f"{prefix}[{i}]", val, out)
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        out[prefix] = float(obj)


def main():
    root = pathlib.Path(__file__).resolve().parent.parent
    fresh_dir = root / "results"
    base_dir = fresh_dir / "baselines"
    baselines = sorted(base_dir.glob("BENCH_*.json")) if base_dir.is_dir() else []
    if not baselines:
        print("diff_bench: no committed BENCH_*.json under results/baselines/ — skipping")
        print("            (capture some with: ci/perf_smoke.sh --baseline)")
        return 0

    for base in baselines:
        fresh = fresh_dir / base.name
        print(f"== {base.name} (fresh vs committed baseline) ==")
        if not fresh.is_file():
            print("  no fresh result in this run")
            continue
        old, new = {}, {}
        numbers("", json.loads(base.read_text()), old)
        numbers("", json.loads(fresh.read_text()), new)
        moved = 0
        for key in sorted(old):
            if key not in new:
                print(f"  {key}: {old[key]:g} -> (gone)")
                moved += 1
            elif new[key] != old[key]:
                if old[key] != 0:
                    rel = 100.0 * (new[key] - old[key]) / abs(old[key])
                    print(f"  {key}: {old[key]:g} -> {new[key]:g} ({rel:+.1f}%)")
                else:
                    print(f"  {key}: {old[key]:g} -> {new[key]:g}")
                moved += 1
        for key in sorted(set(new) - set(old)):
            print(f"  {key}: (new) {new[key]:g}")
            moved += 1
        if moved == 0:
            print("  identical")

    print("diff_bench: report only — baselines never gate the build")
    return 0


if __name__ == "__main__":
    sys.exit(main())
