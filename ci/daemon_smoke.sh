#!/usr/bin/env bash
# Daemon smoke: a real two-process deployment drill.
#
# 1. train and save a v1 model, then `retrain` it into a v2 artifact
#    (the thing the hot-swap publishes);
# 2. start `nle daemon` on v1 as a separate process;
# 3. drive it with the closed-loop load generator: concurrent clients,
#    a `swap` control command landing mid-load, p50/p99 recorded
#    before/during/after -> results/BENCH_serve_daemon.json. The
#    generator exits nonzero if any request is dropped, any response
#    errors, any client sees the version go backwards, or the post-swap
#    phase is not entirely on the swapped version;
# 4. shut the daemon down over the protocol and require a clean exit.
#
# Usage: ci/daemon_smoke.sh   (SKIP_BUILD=1 reuses target/release/nle,
#                              ADDR=host:port overrides 127.0.0.1:7979)
set -euo pipefail
cd "$(dirname "$0")/.."

if [ "${SKIP_BUILD:-0}" != 1 ]; then
  cargo build --release
fi
NLE=target/release/nle
ADDR="${ADDR:-127.0.0.1:7979}"
HOST="${ADDR%%:*}"
PORT="${ADDR##*:}"
mkdir -p results

echo "== train v1 =="
"$NLE" save --data swiss --n 1500 --knn 12 --max-iters 40 \
  --out results/daemon_v1.nlem

echo "== retrain v2 (the artifact the mid-load swap publishes) =="
"$NLE" retrain --model results/daemon_v1.nlem --data swiss --n-new 200 \
  --seed 9 --max-iters 20 --out results/daemon_v2.nlem

echo "== start daemon on $ADDR =="
"$NLE" daemon --model results/daemon_v1.nlem --listen "$ADDR" &
DAEMON_PID=$!
trap 'kill "$DAEMON_PID" 2>/dev/null || true' EXIT

# readiness: probe the accept loop (the bind happens before the
# "listening" log line, so a successful connect means it is serving)
ready=0
for _ in $(seq 1 150); do
  if (exec 3<>"/dev/tcp/$HOST/$PORT") 2>/dev/null; then
    ready=1
    break
  fi
  sleep 0.2
done
if [ "$ready" != 1 ]; then
  echo "daemon did not become ready on $ADDR" >&2
  exit 1
fi

echo "== closed-loop load with mid-run hot-swap =="
# --shutdown-after ends with a protocol `shutdown`, so the daemon
# process must exit 0 on its own — that is the clean-exit assertion
"$NLE" daemon-load --addr "$ADDR" --swap results/daemon_v2.nlem \
  --clients 6 --requests 30 --warmup 8 --shutdown-after

wait "$DAEMON_PID"
trap - EXIT

test -s results/BENCH_serve_daemon.json
grep -q '"dropped": 0' results/BENCH_serve_daemon.json
grep -q '"versions_monotone": true' results/BENCH_serve_daemon.json
grep -q '"swapped_version": 2' results/BENCH_serve_daemon.json
echo "daemon smoke OK"
