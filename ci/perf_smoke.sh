#!/usr/bin/env bash
# Perf smoke: the per-commit performance trajectory, runnable locally
# and by the CI perf-smoke job (which uploads results/ as artifacts).
#
# Runs the scal / ann / init / multigrid / serve harnesses plus the
# checkpoint -> kill -> resume equivalence assertion, writing CSVs and
# machine-readable BENCH_*.json under results/. With PERF_GATE=<pct>
# set, finishes by running ci/diff_bench.py --max-regress <pct>
# against the committed baselines (report-only otherwise).
#
# Usage: ci/perf_smoke.sh [--full] [--baseline] [--skip-build]
#   --full       acceptance-scale runs (the EXPERIMENTS.md baseline
#                settings: scal at N=4096..65536, init at N=16384, ...)
#                instead of the PR-sized smokes; also FULL=1
#   --baseline   after the runs, copy every fresh results/BENCH_*.json
#                into results/baselines/ — commit those to pin the
#                numbers ci/diff_bench.py reports against
#   --skip-build reuse an existing target/release/nle
set -euo pipefail
cd "$(dirname "$0")/.."

FULL="${FULL:-0}"
BASELINE=0
SKIP_BUILD=0
for arg in "$@"; do
  case "$arg" in
    --full) FULL=1 ;;
    --baseline) BASELINE=1 ;;
    --skip-build) SKIP_BUILD=1 ;;
    *) echo "unknown argument: $arg" >&2; exit 2 ;;
  esac
done

if [ "$SKIP_BUILD" != 1 ]; then
  cargo build --release
fi
NLE=target/release/nle
mkdir -p results

if [ "$FULL" = 1 ]; then
  SCAL_SIZES=4096,16384,65536 SCAL_REPS=3 SD_ITERS=5
  ANN_SIZES=2000,5000,10000,20000
  INIT_N=16384 INIT_ITERS=200
  MG_N=65536 MG_ITERS=100
  SERVE_N=4096 SERVE_BATCHES=1,16,256,1024 SERVE_ITERS=30 SERVE_REPS=3
  DL_N=4096 DL_ITERS=30 DL_CLIENTS=8 DL_REQUESTS=40
else
  SCAL_SIZES=1024,2048 SCAL_REPS=1 SD_ITERS=2
  ANN_SIZES=1024,2048
  INIT_N=2048 INIT_ITERS=60
  MG_N=2048 MG_ITERS=60
  SERVE_N=2048 SERVE_BATCHES=1,64,512 SERVE_ITERS=10 SERVE_REPS=2
  DL_N=1024 DL_ITERS=10 DL_CLIENTS=6 DL_REQUESTS=25
fi

# all four gradient engines: exact reference, Barnes-Hut theta = 0.5,
# negative sampling k = 64, grid interpolation g = 128
# -> results/scalability.csv + BENCH_scal.json
echo "== scal =="
"$NLE" scal --sizes "$SCAL_SIZES" --thetas 0.5 --neg 64 --grid 128 \
  --reps "$SCAL_REPS" --sd-iters "$SD_ITERS"

echo "== ann =="
"$NLE" ann --sizes "$ANN_SIZES"

# random vs spectral warm start: init wall-clock and
# iterations-to-quality -> results/init.csv + BENCH_init.json
echo "== init =="
"$NLE" init --n "$INIT_N" --inits random,spectral:rsvd,spectral:lanczos \
  --max-iters "$INIT_ITERS"

# coarse-to-fine over the HNSW hierarchy vs flat training on the same
# problem; --require-bar makes the run itself assert the staged path
# reaches the flat run's quality bar (or matches its kNN recall)
# -> results/multigrid.csv + BENCH_multigrid.json
echo "== multigrid =="
"$NLE" multigrid --n "$MG_N" --max-iters "$MG_ITERS" --require-bar

echo "== serve =="
"$NLE" serve --n "$SERVE_N" --batches "$SERVE_BATCHES" \
  --train-iters "$SERVE_ITERS" --reps "$SERVE_REPS"
echo "== serve (1 thread) =="
NLE_THREADS=1 "$NLE" serve --n "$SERVE_N" --batches 64,512 \
  --train-iters "$SERVE_ITERS" --reps "$SERVE_REPS" \
  --csv serve_t1.csv --json BENCH_serve_t1.json

# the serving daemon under closed-loop load with a mid-run hot-swap
# (self-hosted: trains v1, warm-start-retrains v2, swaps it in over the
# wire) -> results/BENCH_serve_daemon.json; the run itself asserts zero
# dropped requests and monotone versions
echo "== daemon-load (self-host) =="
"$NLE" daemon-load --n "$DL_N" --train-iters "$DL_ITERS" \
  --clients "$DL_CLIENTS" --requests "$DL_REQUESTS"

# checkpoint -> kill -> resume: run 25 iterations checkpointing every
# 10 (simulating a preempted job whose last record landed mid-run at
# iteration 20), resume to the full 60-iteration budget, and require
# the final energy to match an uninterrupted 60-iteration run digit
# for digit (the embed printout carries 12 fractional digits) — the
# CI-sized version of the bitwise resume-equivalence contract in
# rust/tests/resume_roundtrip.rs
echo "== checkpoint/resume =="
"$NLE" embed --data swiss --n 1024 --knn 12 --strategy gd \
  --max-iters 25 --checkpoint-every 10 --checkpoint-path results/ckpt.nlec \
  --out results/embed_part.csv | tee /tmp/part.log
"$NLE" embed --data swiss --n 1024 --knn 12 --strategy gd \
  --max-iters 60 --resume results/ckpt.nlec \
  --out results/embed_resumed.csv | tee /tmp/resumed.log
"$NLE" embed --data swiss --n 1024 --knn 12 --strategy gd \
  --max-iters 60 \
  --out results/embed_full.csv | tee /tmp/full.log
E_RESUMED=$(grep -o 'E = [^,]*' /tmp/resumed.log | tail -n 1)
E_FULL=$(grep -o 'E = [^,]*' /tmp/full.log | tail -n 1)
echo "resumed:       $E_RESUMED"
echo "uninterrupted: $E_FULL"
test -n "$E_RESUMED"
[ "$E_RESUMED" = "$E_FULL" ]

if [ "$BASELINE" = 1 ]; then
  mkdir -p results/baselines
  cp results/BENCH_*.json results/baselines/
  echo "baselines refreshed under results/baselines/ — review and commit"
fi

# perf trajectory vs the committed baselines: report-only by default,
# a hard gate when PERF_GATE=<max regression pct> is set (silent pass
# while results/baselines/ is empty either way)
echo "== diff vs baselines =="
if [ -n "${PERF_GATE:-}" ]; then
  python3 ci/diff_bench.py --max-regress "$PERF_GATE"
else
  python3 ci/diff_bench.py
fi

echo "perf smoke OK"
